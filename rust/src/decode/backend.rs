//! Pluggable decode backends: the LM layer a [`DecodeSession`] projects,
//! unembeds and picks tokens with.
//!
//! [`DecodeBackend`] extracts exactly the model surface the decode stack
//! touches — prompt-ingest K/V projection, the single-position QKV
//! projection of a step, logit production for a step (or a batched γ+1
//! verify position) and greedy token selection — so the session, the
//! speculative draft/verify loop and the coordinator hold an
//! `Arc<dyn DecodeBackend>` instead of a concrete model:
//!
//! * [`TinyLm`] (the seeded in-process reference LM) implements the
//!   trait as the fast deterministic default — every test that does not
//!   care about the real model keeps its exact pre-trait streams.
//! * [`EngineBackend`] routes per-step logits through compiled
//!   `decode_step` modules served by a [`PrefillBackend`] (the PJRT
//!   [`Engine`](crate::runtime::Engine) against real artifacts, or the
//!   artifact-free [`SyntheticEngine`](crate::runtime::SyntheticEngine)
//!   in CI): the token history is padded to the smallest decode context
//!   bucket and executed as one ids→logits forward, and the logits row
//!   at the last real position decides the token. K/V projections come
//!   from a checkpoint-seeded projection core with the manifest
//!   geometry, so the paged-KV store, the sparse kernels and the
//!   speculative rollback machinery run unchanged underneath the
//!   compiled logits.
//!
//! Determinism contract: `step_logits` must be a pure function of the
//! token history prefix (plus the attention output it may fall back on),
//! because the byte-exact spec==sequential equivalence suite
//! (`rust/tests/spec_equivalence.rs`) runs per backend — a backend whose
//! verify-position logits differ from its sequential-step logits would
//! corrupt committed streams, not just waste drafts.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::model::vocab;
use crate::runtime::engine::PrefillBackend;

use super::session::TinyLm;

/// Deterministic greedy pick (ties break toward the lowest id) — the
/// shared selection rule every backend defaults to.
pub fn greedy_argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &v) in logits.iter().enumerate() {
        if v > logits[best] {
            best = i;
        }
    }
    best as i32
}

/// The LM surface of the decode stack (see module docs). Implementations
/// must be deterministic: same inputs, same outputs, at any thread count.
pub trait DecodeBackend: Send + Sync {
    /// Query heads.
    fn heads(&self) -> usize;

    /// K/V heads (GQA groups).
    fn kv_heads(&self) -> usize;

    /// Head dimension.
    fn head_dim(&self) -> usize;

    /// Vocabulary size of the logits this backend produces.
    fn vocab(&self) -> usize;

    /// Model width (`heads · head_dim` unless the backend overrides).
    fn d_model(&self) -> usize {
        self.heads() * self.head_dim()
    }

    /// Stable label for config/metrics surfaces (`"tiny"`, `"engine"`).
    fn name(&self) -> &'static str;

    /// Project one token at `pos`: `(Some(q) if with_q, k, v)`, each
    /// `[heads·dh]` row-major. Prompt ingestion skips the q projection.
    fn project(&self, token: i32, pos: usize, with_q: bool)
        -> (Option<Vec<f32>>, Vec<f32>, Vec<f32>);

    /// Unembed an attention output (`[heads·dh]`) into vocab logits —
    /// the context-free half of a step; backends whose logits depend on
    /// the token history override [`DecodeBackend::step_logits`] instead.
    fn logits(&self, attn_out: &[f32]) -> Vec<f32>;

    /// Logits for the decode step conditioned on `history` — every token
    /// whose K/V is cached, in stream order (the step's own conditioning
    /// token last). `attn_out` is that step's policy-directed attention
    /// output; the default implementation unembeds it via
    /// [`DecodeBackend::logits`], while module-executing backends use
    /// the history as the ids of a compiled forward. The speculative
    /// verify calls this once per γ+1 position with the matching history
    /// prefix, so it must be a pure function of its inputs.
    fn step_logits(&self, history: &[i32], attn_out: &[f32]) -> Vec<f32> {
        let _ = history;
        self.logits(attn_out)
    }

    /// Pick the emitted token from a step's logits (greedy, lowest-id
    /// tie-break by default).
    fn select(&self, logits: &[f32]) -> i32 {
        greedy_argmax(logits)
    }
}

impl DecodeBackend for TinyLm {
    fn heads(&self) -> usize {
        self.h
    }

    fn kv_heads(&self) -> usize {
        self.hk
    }

    fn head_dim(&self) -> usize {
        self.dh
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn d_model(&self) -> usize {
        TinyLm::d_model(self)
    }

    fn name(&self) -> &'static str {
        "tiny"
    }

    fn project(
        &self,
        token: i32,
        pos: usize,
        with_q: bool,
    ) -> (Option<Vec<f32>>, Vec<f32>, Vec<f32>) {
        TinyLm::project(self, token, pos, with_q)
    }

    fn logits(&self, attn_out: &[f32]) -> Vec<f32> {
        TinyLm::logits(self, attn_out)
    }
}

/// Which decode backend a serving stack should construct — the config
/// surface behind `CoordinatorConfig::decode_backend` and the
/// `--decode-backend {tiny,engine}` CLI flag.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum DecodeBackendKind {
    /// The in-process deterministic [`TinyLm`] (fast test default).
    #[default]
    Tiny,
    /// Compiled per-step decode modules through [`EngineBackend`].
    Engine,
}

impl DecodeBackendKind {
    /// Parse the CLI spelling (`"tiny"` / `"engine"`).
    pub fn parse(s: &str) -> Option<DecodeBackendKind> {
        match s {
            "tiny" => Some(DecodeBackendKind::Tiny),
            "engine" => Some(DecodeBackendKind::Engine),
            _ => None,
        }
    }

    /// The stable label ([`DecodeBackend::name`]) this kind resolves to.
    pub fn label(self) -> &'static str {
        match self {
            DecodeBackendKind::Tiny => "tiny",
            DecodeBackendKind::Engine => "engine",
        }
    }

    /// Resolve this kind into a live backend over `engine`'s manifest.
    /// `Tiny` seeds the deterministic in-process LM with the manifest
    /// geometry (the serving seed every pre-trait stream was pinned
    /// under); `Engine` wraps the manifest's compiled `decode_step`
    /// modules under its first listed checkpoint (or `"base"` when the
    /// manifest names none). Only the `Engine` arm can fail — on
    /// artifacts that predate the decode lowering.
    pub fn build(self, engine: &Arc<dyn PrefillBackend>) -> Result<Arc<dyn DecodeBackend>> {
        let m = &engine.manifest().model;
        match self {
            DecodeBackendKind::Tiny => Ok(Arc::new(TinyLm::new(
                0xD0C0DE,
                m.n_heads,
                m.n_kv_heads.max(1),
                m.d_head,
                m.vocab_size,
            ))),
            DecodeBackendKind::Engine => {
                let checkpoint = engine
                    .manifest()
                    .weights
                    .first()
                    .map(|(name, _)| name.clone())
                    .unwrap_or_else(|| "base".to_string());
                Ok(Arc::new(EngineBackend::new(Arc::clone(engine), &checkpoint)?))
            }
        }
    }
}

/// Decode backend over compiled `decode_step` modules (see module docs):
/// per-step logits execute the token history through the smallest
/// manifest decode bucket that covers it, via the same
/// [`PrefillBackend`] weight-pinning path prefill uses; K/V projections
/// come from a checkpoint-seeded projection core with the manifest
/// geometry, so paging, sparse attention and speculative rollback are
/// exercised unchanged.
pub struct EngineBackend {
    engine: Arc<dyn PrefillBackend>,
    checkpoint: String,
    /// Checkpoint-seeded projection core with the manifest geometry —
    /// supplies K/V (and q) rows plus the unembed fallback once the
    /// context outgrows every decode bucket.
    proj: TinyLm,
    /// Sorted distinct `decode_step` context buckets from the manifest.
    buckets: Vec<usize>,
    vocab: usize,
    overflow_warned: AtomicBool,
}

impl EngineBackend {
    /// Module kind of the per-step decode graphs this backend executes.
    pub const KIND: &'static str = "decode_step";

    /// Build over `engine`'s manifest: geometry from `manifest.model`,
    /// buckets from its `decode_step` modules (at least one required —
    /// artifacts predating the decode lowering fail loudly here instead
    /// of silently decoding with the projection core).
    pub fn new(engine: Arc<dyn PrefillBackend>, checkpoint: &str) -> Result<EngineBackend> {
        let m = engine.manifest();
        let mut buckets: Vec<usize> = m
            .modules
            .iter()
            .filter(|mo| mo.kind == Self::KIND)
            .map(|mo| mo.n_ctx)
            .collect();
        buckets.sort_unstable();
        buckets.dedup();
        if buckets.is_empty() {
            bail!(
                "manifest has no `{}` modules — re-run the aot compile path \
                 (python/compile/aot.py) to lower per-step decode graphs",
                Self::KIND
            );
        }
        let (model, vocab) = (&m.model, m.model.vocab_size);
        let proj = TinyLm::new(
            Self::seed_for(checkpoint),
            model.n_heads,
            model.n_kv_heads.max(1),
            model.d_head,
            vocab,
        );
        Ok(EngineBackend {
            engine,
            checkpoint: checkpoint.to_string(),
            proj,
            buckets,
            vocab,
            overflow_warned: AtomicBool::new(false),
        })
    }

    /// Deterministic per-checkpoint projection seed (FNV-1a over the
    /// checkpoint name): distinct checkpoints get distinct K/V streams,
    /// and — by construction — streams distinct from the default
    /// `TinyLm` test seeds, so per-backend test pins actually
    /// discriminate.
    fn seed_for(checkpoint: &str) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        for b in checkpoint.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }

    /// Sorted decode context buckets this backend can execute.
    pub fn buckets(&self) -> &[usize] {
        &self.buckets
    }

    /// Smallest decode bucket covering a history of `n` tokens (`None`
    /// once the context outgrows every lowered module).
    pub fn bucket_for(&self, n: usize) -> Option<usize> {
        self.buckets.iter().copied().find(|&b| b >= n)
    }
}

impl DecodeBackend for EngineBackend {
    fn heads(&self) -> usize {
        self.proj.h
    }

    fn kv_heads(&self) -> usize {
        self.proj.hk
    }

    fn head_dim(&self) -> usize {
        self.proj.dh
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn name(&self) -> &'static str {
        "engine"
    }

    fn project(
        &self,
        token: i32,
        pos: usize,
        with_q: bool,
    ) -> (Option<Vec<f32>>, Vec<f32>, Vec<f32>) {
        self.proj.project(token, pos, with_q)
    }

    fn logits(&self, attn_out: &[f32]) -> Vec<f32> {
        self.proj.logits(attn_out)
    }

    fn step_logits(&self, history: &[i32], attn_out: &[f32]) -> Vec<f32> {
        let n = history.len();
        let bucket = match (n > 0).then(|| self.bucket_for(n)).flatten() {
            Some(b) => b,
            None => {
                // context outgrew every lowered decode bucket (or an
                // empty history): fall back to unembedding the attention
                // output — deterministic, but no longer the compiled
                // model. Warn once so the degradation is visible.
                if n > 0 && !self.overflow_warned.swap(true, Ordering::Relaxed) {
                    crate::info!(
                        "engine decode: context {} outgrew the largest decode bucket {} — \
                         falling back to the projection-core unembed",
                        n,
                        self.buckets.last().copied().unwrap_or(0)
                    );
                }
                return self.proj.logits(attn_out);
            }
        };
        let mut ids = history.to_vec();
        ids.resize(bucket, vocab::PAD);
        match self.engine.prefill(&self.checkpoint, Self::KIND, bucket, &ids, &[]) {
            Ok(out) => {
                debug_assert_eq!(out.vocab, self.vocab, "manifest vocab drift");
                out.logits[(n - 1) * out.vocab..n * out.vocab].to_vec()
            }
            Err(e) => {
                // execution failure degrades to the deterministic local
                // unembed rather than poisoning the whole session; the
                // flight recorder / logs carry the cause
                if !self.overflow_warned.swap(true, Ordering::Relaxed) {
                    crate::info!("engine decode: module execution failed ({e:#}) — falling back");
                }
                self.proj.logits(attn_out)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::{Manifest, ModelConfig, ModuleInfo};
    use crate::runtime::engine::{PrefillOutput, ScalarValue};
    use crate::runtime::SyntheticEngine;

    #[test]
    fn tiny_lm_implements_the_trait_faithfully() {
        let lm = TinyLm::new(7, 4, 2, 8, vocab::VOCAB_SIZE);
        let b: &dyn DecodeBackend = &lm;
        assert_eq!((b.heads(), b.kv_heads(), b.head_dim()), (4, 2, 8));
        assert_eq!(b.d_model(), 32);
        assert_eq!(b.name(), "tiny");
        let attn = vec![0.25f32; 32];
        assert_eq!(b.logits(&attn), lm.logits(&attn));
        // the default step_logits ignores the history entirely
        assert_eq!(b.step_logits(&[1, 2, 3], &attn), lm.logits(&attn));
        let l = b.logits(&attn);
        assert_eq!(b.select(&l), TinyLm::argmax(&l));
    }

    #[test]
    fn engine_backend_executes_decode_modules() {
        let eng = Arc::new(SyntheticEngine::new(&[64, 128]));
        let be = EngineBackend::new(eng.clone(), "base").unwrap();
        assert_eq!(be.name(), "engine");
        assert_eq!(be.buckets(), &[64, 128]);
        assert_eq!(be.bucket_for(65), Some(128));
        assert_eq!(be.bucket_for(129), None);
        let m = eng.manifest().model.clone();
        assert_eq!((be.heads(), be.kv_heads(), be.head_dim()), (4, 2, 16));
        let history = [vocab::BOS, 5, 9, 2];
        let attn = vec![0.0f32; be.d_model()];
        let logits = be.step_logits(&history, &attn);
        assert_eq!(logits.len(), m.vocab_size);
        // the synthetic engine's hot logit is a pure function of the last
        // real (token, position) pair — exactly the row the backend reads
        let n = history.len();
        let hot = (history[n - 1] as u64)
            .wrapping_mul(0x9e37_79b9)
            .wrapping_add((n - 1) as u64)
            % m.vocab_size as u64;
        assert_eq!(be.select(&logits), hot as i32);
        // deterministic per history prefix
        assert_eq!(be.step_logits(&history, &attn), logits);
        // and genuinely different from the TinyLm default for the same
        // attention output (the whole point of the backend split)
        let tiny = TinyLm::new(7, 4, 2, 16, m.vocab_size);
        assert_ne!(DecodeBackend::step_logits(&tiny, &history, &attn), logits);
    }

    #[test]
    fn engine_backend_falls_back_past_the_largest_bucket() {
        let eng = Arc::new(SyntheticEngine::new(&[64]));
        let be = EngineBackend::new(eng, "base").unwrap();
        let history = vec![3i32; 65]; // > largest decode bucket
        let attn = vec![0.5f32; be.d_model()];
        assert_eq!(be.step_logits(&history, &attn), be.logits(&attn));
        // empty history (no cached tokens) also unembeds locally
        assert_eq!(be.step_logits(&[], &attn), be.logits(&attn));
    }

    #[test]
    fn distinct_checkpoints_project_distinct_kv() {
        let eng = Arc::new(SyntheticEngine::new(&[64]));
        let a = EngineBackend::new(eng.clone(), "base").unwrap();
        let b = EngineBackend::new(eng, "other").unwrap();
        let (_, ka, _) = a.project(5, 3, false);
        let (_, kb, _) = b.project(5, 3, false);
        assert_ne!(ka, kb, "checkpoint seed must differentiate projections");
    }

    /// A manifest without decode modules (pre-refactor artifacts).
    struct PrefillOnly(Manifest);

    impl PrefillBackend for PrefillOnly {
        fn manifest(&self) -> &Manifest {
            &self.0
        }

        fn prefill(
            &self,
            _checkpoint: &str,
            _kind: &str,
            _n_ctx: usize,
            _ids: &[i32],
            _scalars: &[ScalarValue],
        ) -> Result<PrefillOutput> {
            bail!("unused")
        }
    }

    #[test]
    fn construction_fails_loudly_without_decode_modules() {
        let man = Manifest {
            root: std::path::PathBuf::new(),
            model: ModelConfig {
                vocab_size: vocab::VOCAB_SIZE,
                d_model: 64,
                n_layers: 2,
                n_heads: 4,
                n_kv_heads: 2,
                d_ff: 128,
                block: 16,
                init_keep: 1,
                local_keep: 2,
                min_total: 3,
                d_head: 16,
            },
            param_spec: vec![],
            weights: vec![],
            modules: vec![ModuleInfo {
                name: "prefill_stem_128".into(),
                kind: "prefill_stem".into(),
                n_ctx: 128,
                file: String::new(),
                scalars: vec![],
                outputs: vec!["logits".into(), "budget_fraction".into()],
            }],
            eval_sets: vec![],
            defaults: vec![],
        };
        let err = EngineBackend::new(Arc::new(PrefillOnly(man)), "base").unwrap_err();
        assert!(err.to_string().contains("decode_step"), "{err}");
    }
}
