//! Observability integration suite: drives the full coordinator
//! (synthetic backend, no artifacts) and asserts the three telemetry
//! surfaces added by `stem::obs` hold together end to end:
//!
//! * the flight recorder reconstructs every generation branch as one
//!   complete span — submit → terminal finish — out of the global ring;
//! * an injected decode panic leaves a `panic site=decode` event on the
//!   failing span, and its failure dump is headed by a `STEM_FAULTS`
//!   replay line that parses back into the live plan;
//! * [`stem::coordinator::Coordinator::snapshot`] is coherent with the
//!   traffic driven (counters, KV gauges, trace stats, sparsity bands
//!   accounting for every decode step) and serializes to valid JSON and
//!   well-formed Prometheus text.
//!
//! The decode-driving properties run once per decode backend (`tiny`
//! and `engine` — the latter served by the synthetic engine's
//! `decode_step` modules), so the telemetry contract holds whichever
//! backend the coordinator decodes with.

use std::sync::Arc;
use std::time::Duration;

use stem::coordinator::{Coordinator, CoordinatorConfig, Finish, Method};
use stem::decode::{DecodeBackendKind, DecodePolicy};
use stem::obs::trace::{EventKind, Outcome, PanicSite};
use stem::runtime::{PrefillBackend, SyntheticEngine};
use stem::util::fault::{FaultPlan, FaultPoint};
use stem::util::json::Json;

/// Terminal-outcome bound (synthetic backend: anything near this hangs).
const TERMINAL: Duration = Duration::from_secs(60);

const BACKENDS: [DecodeBackendKind; 2] = [DecodeBackendKind::Tiny, DecodeBackendKind::Engine];

fn coordinator(faults: Option<Arc<FaultPlan>>, decode_backend: DecodeBackendKind) -> Coordinator {
    let engine: Arc<dyn PrefillBackend> = Arc::new(SyntheticEngine::new(&[128, 256]));
    Coordinator::with_backend(
        engine,
        CoordinatorConfig { workers: 2, kv_pages: 256, faults, decode_backend, ..Default::default() },
    )
}

#[test]
fn every_generation_span_runs_submit_to_terminal() {
    for kind in BACKENDS {
        every_generation_span_case(kind);
    }
}

fn every_generation_span_case(kind: DecodeBackendKind) {
    let coord = coordinator(None, kind);
    let prompt: Vec<i32> = (0..24).map(|i| 16 + (i % 64)).collect();
    let tickets = coord
        .submit_generate_tickets(prompt, 6, DecodePolicy::default(), 3, None)
        .expect("submit");
    let seqs: Vec<u64> = tickets.iter().map(|t| t.seq()).collect();
    for mut t in tickets {
        let resp = t.recv_timeout(TERMINAL).expect("terminal outcome");
        assert_eq!(resp.finish, Finish::Complete);
    }
    let rec = coord.flight_recorder().expect("tracing is on by default");
    for seq in seqs {
        let ev = rec.span_events(seq);
        assert!(
            matches!(ev.first().map(|e| e.kind), Some(EventKind::Submit { .. })),
            "span {seq} must open with submit: {ev:?}"
        );
        assert!(
            matches!(
                ev.last().map(|e| e.kind),
                Some(EventKind::Finish { outcome: Outcome::Complete })
            ),
            "span {seq} must close complete: {ev:?}"
        );
        assert!(
            ev.iter().any(|e| matches!(e.kind, EventKind::PrefixRoute { .. })),
            "span {seq} must record its prefix-route decision: {ev:?}"
        );
        assert!(
            ev.iter().any(|e| matches!(e.kind, EventKind::DecodeStep { .. })),
            "span {seq} must record decode progress: {ev:?}"
        );
    }
}

#[test]
fn injected_decode_panic_leaves_span_and_replayable_dump() {
    let plan = Arc::new(FaultPlan::new(5).with_rate(FaultPoint::DecodeStep, 1.0));
    let coord = coordinator(Some(Arc::clone(&plan)), DecodeBackendKind::default());
    let mut ts = coord
        .submit_generate_tickets(vec![1, 20, 21, 22], 4, DecodePolicy::default(), 1, None)
        .expect("submit");
    let mut t = ts.pop().expect("one branch");
    let seq = t.seq();
    t.recv_timeout(TERMINAL).expect_err("every decode step panics under step=1");

    let rec = coord.flight_recorder().expect("tracing is on by default");
    let ev = rec.span_events(seq);
    assert!(
        ev.iter().any(|e| matches!(e.kind, EventKind::Panic { site: PanicSite::Decode })),
        "the caught panic must land on the failing span: {ev:?}"
    );
    assert!(
        matches!(ev.last().map(|e| e.kind), Some(EventKind::Finish { outcome: Outcome::Error })),
        "the panicked branch must still terminate its span: {ev:?}"
    );

    // the dump the panic handler prints: full span + replay header that
    // parses back into an equivalent plan
    let dump = rec.render_failure_dump(Some(seq), Some(&plan.spec_string()));
    assert!(dump.contains("replay: STEM_FAULTS='seed=5,step=1'"), "{dump}");
    assert!(dump.contains("submit tokens=4"), "{dump}");
    assert!(dump.contains("panic site=decode"), "{dump}");
    assert!(dump.contains("finish outcome=error"), "{dump}");
    FaultPlan::parse(&plan.spec_string()).expect("replay line must parse");

    // metrics agree: the panic was isolated, not fatal
    assert_eq!(coord.snapshot().worker_panics, 1);
}

#[test]
fn snapshot_json_and_prometheus_cohere_with_driven_traffic() {
    for kind in BACKENDS {
        snapshot_coherence_case(kind);
    }
}

fn snapshot_coherence_case(kind: DecodeBackendKind) {
    let coord = coordinator(None, kind);

    // one prefill through the batcher + worker pool
    let ids: Vec<i32> = (0..64).map(|i| 16 + (i % 64)).collect();
    let method = Method::Stem { k_start: 4.0, mu: 0.7, beta: 0.2 };
    let rx = coord.submit_with_deadline("base", method, ids, false, None).expect("submit");
    rx.recv().expect("channel").expect("prefill completes");

    // eight generation branches across four groups
    let mut tickets = Vec::new();
    for r in 0..4i32 {
        let prompt: Vec<i32> = (0..12).map(|i| 20 + ((i + r) % 40)).collect();
        tickets.extend(
            coord
                .submit_generate_tickets(prompt, 8, DecodePolicy::default(), 2, None)
                .expect("submit"),
        );
    }
    for mut t in tickets {
        assert_eq!(t.recv_timeout(TERMINAL).expect("terminal").finish, Finish::Complete);
    }

    let snap = coord.snapshot();
    assert_eq!(
        snap.decode_backend,
        Some(kind.label()),
        "snapshot must carry the decode backend it was driven with"
    );
    assert_eq!(snap.submitted, 1);
    assert_eq!(snap.completed, 1);
    assert_eq!(snap.generates_submitted, 8);
    assert_eq!(snap.generates_completed, 8);
    assert!(snap.decode_steps >= 8, "eight branches decoded: {}", snap.decode_steps);
    // position-band gauges account for every decode step exactly once
    assert_eq!(snap.sparsity.iter().map(|b| b.steps).sum::<u64>(), snap.decode_steps);
    let kv = snap.kv.expect("the coordinator attaches pool gauges");
    assert_eq!(kv.pages_total, 256);
    let trace = snap.trace.expect("tracing is on by default");
    assert!(trace.recorded > 0);

    // JSON: parses, carries the versioned schema and the live values
    let j = Json::parse(&snap.to_json().to_string()).expect("export must be valid JSON");
    assert_eq!(j.path("schema_version").and_then(Json::as_i64), Some(1));
    assert_eq!(j.path("requests.generates_completed").and_then(Json::as_i64), Some(8));
    assert_eq!(
        j.path("decode.steps").and_then(Json::as_i64),
        Some(snap.decode_steps as i64)
    );
    assert_eq!(j.path("decode.backend").and_then(Json::as_str), Some(kind.label()));
    assert!(j.path("kv.occupancy").is_some());
    assert!(j.path("trace.recorded").and_then(Json::as_i64).unwrap_or(0) > 0);

    // Prometheus: key series present with matching values, histogram
    // buckets cumulative
    let text = snap.to_prometheus();
    assert!(text.contains("stem_generates_completed_total 8"));
    assert!(text.contains("# TYPE stem_decode_step_us histogram"));
    assert!(text.contains("stem_kv_pages_total 256"));
    assert!(text.contains("stem_trace_events_recorded"));
    assert!(
        text.contains(&format!("stem_decode_backend_info{{backend=\"{}\"}} 1", kind.label())),
        "{text}"
    );
    // short-context traffic lands in the lowest band
    assert!(text.contains("stem_sparsity_steps_total{band=\"lt1k\"}"), "{text}");
    let mut prev = 0u64;
    for line in text.lines().filter(|l| l.starts_with("stem_decode_step_us_bucket{le=\"")) {
        if line.contains("+Inf") {
            continue;
        }
        let count: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
        assert!(count >= prev, "buckets must be cumulative: {line}");
        prev = count;
    }
}
