//! Cross-language golden tests: the pure-rust sparse core must reproduce
//! the python jnp oracles bit-for-bit-ish (f32 tolerance) on the golden
//! vectors exported by `python/compile/aot.py` (artifacts/golden/).
//!
//! Skips (with a note) when artifacts are absent so `cargo test` stays
//! green pre-`make artifacts`; CI runs it after the artifact build.
//!
//! The degenerate-shape section at the bottom needs no artifacts: it pins
//! all-masked rows, sub-lane tails and extreme score magnitudes against
//! the scalar oracle under BOTH SIMD dispatch arms (explicit arms only —
//! the process-global override is never touched, so the parallel test
//! runner stays race-free).

use stem::sparse::simd::{arm_label, ARMS};
use stem::sparse::{
    block_sparse_attention, block_sparse_attention_reference, block_sparse_attention_with,
    dense_decode_attention_reference, dense_verify_attention_reference, oam_scores,
    sparse_decode_attention_with, sparse_verify_attention_with, KvBlocks, Selection,
    SelectionBuilder, Tensor, TensorKv,
};
use stem::util::json::Json;
use stem::util::rng::Rng;

struct Golden {
    block: usize,
    h: usize,
    hk: usize,
    n: usize,
    dh: usize,
    q: Tensor,
    k: Tensor,
    v: Tensor,
    indices: Vec<i64>,
    counts: Vec<i64>,
    attention_out: Vec<f32>,
    oam: Vec<f32>,
}

fn load_golden() -> Option<Golden> {
    let path = stem::artifacts_dir().join("golden/kernels.json");
    let text = std::fs::read_to_string(&path).ok()?;
    let j = Json::parse(&text).ok()?;
    let us = |k: &str| j.get(k).and_then(Json::as_usize).unwrap();
    let fv = |k: &str| -> Vec<f32> {
        j.get(k)
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap() as f32)
            .collect()
    };
    let iv = |k: &str| -> Vec<i64> {
        j.get(k).and_then(Json::as_arr).unwrap().iter().map(|x| x.as_i64().unwrap()).collect()
    };
    let (h, hk, n, dh) = (us("h"), us("hk"), us("n"), us("dh"));
    Some(Golden {
        block: us("block"),
        h,
        hk,
        n,
        dh,
        q: Tensor::from_vec(&[h, n, dh], fv("q")),
        k: Tensor::from_vec(&[hk, n, dh], fv("k")),
        v: Tensor::from_vec(&[hk, n, dh], fv("v")),
        indices: iv("indices"),
        counts: iv("counts"),
        attention_out: fv("attention_out"),
        oam: fv("oam_scores"),
    })
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn rust_block_sparse_matches_python_oracle() {
    let Some(g) = load_golden() else {
        eprintln!("skipping: artifacts/golden/kernels.json missing (run `make artifacts`)");
        return;
    };
    let nblk = g.n / g.block;
    // python exports fixed-width rows: CSR keeps them as selected prefix
    // + interface padding under per-row counts
    let mut b = SelectionBuilder::with_capacity(g.h, nblk, g.h * nblk * nblk);
    for h in 0..g.h {
        for i in 0..nblk {
            let row: Vec<u32> = (0..nblk)
                .map(|t| g.indices[(h * nblk + i) * nblk + t] as u32)
                .collect();
            b.push_row(&row, g.counts[h * nblk + i] as u32);
        }
    }
    let sel = b.finish();
    sel.validate().expect("golden selection must satisfy kernel invariants");
    let out = block_sparse_attention(&g.q, &g.k, &g.v, &sel, g.block);
    let d = max_abs_diff(&out.data, &g.attention_out);
    assert!(d < 2e-4, "rust block-sparse deviates from jnp oracle: {d}");
    let reference = block_sparse_attention_reference(&g.q, &g.k, &g.v, &sel, g.block);
    let dr = max_abs_diff(&reference.data, &g.attention_out);
    assert!(dr < 2e-4, "rust reference block-sparse deviates from jnp oracle: {dr}");
    let _ = g.hk;
}

#[test]
fn rust_oam_scores_match_python_oracle() {
    let Some(g) = load_golden() else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    // golden emitted with beta=0.2, stride=16 (aot.py export_goldens)
    let scores = oam_scores(&g.q, &g.k, &g.v, g.block, 16, 0.2);
    let nblk = g.n / g.block;
    let mut worst = 0f32;
    for h in 0..g.h {
        for i in 0..nblk {
            for j in 0..=i {
                let want = g.oam[(h * nblk + i) * nblk + j];
                let got = scores.at3(h, i, j);
                worst = worst.max((want - got).abs());
            }
        }
    }
    assert!(worst < 2e-4, "rust OAM deviates from jnp oracle: {worst}");
}

// --- degenerate shapes under both dispatch arms ---------------------------

#[test]
fn all_masked_rows_zero_identically_on_both_arms() {
    let mut r = Rng::new(41);
    let (h, n, dh, block) = (1usize, 64usize, 8usize, 32usize);
    let q = Tensor::randn(&[h, n, dh], &mut r);
    let k = Tensor::randn(&[h, n, dh], &mut r);
    let v = Tensor::randn(&[h, n, dh], &mut r);
    // row 0 selects only block 1 (non-causal): every score in its tile is
    // the -inf sentinel, so the whole row must come out as exact zeros
    let mut b = SelectionBuilder::new(1, 2);
    b.push_row(&[1], 1);
    b.push_row(&[1, 0], 2);
    let sel = b.finish();
    for arm in ARMS {
        let out = block_sparse_attention_with(arm, &q, &k, &v, &sel, block);
        assert!(
            out.data.iter().all(|x| x.is_finite()),
            "{}: -inf sentinel leaked a NaN",
            arm_label(arm)
        );
        assert!(
            out.data[..block * dh].iter().all(|&x| x == 0.0),
            "{}: masked row must be exact zeros",
            arm_label(arm)
        );
        assert!(
            out.data[block * dh..].iter().any(|&x| x != 0.0),
            "{}: live rows must attend",
            arm_label(arm)
        );
    }
}

#[test]
fn decode_tails_shorter_than_lane_width_agree_across_arms() {
    // context tails below the 8-lane width (and below dh) exercise the
    // scalar tail of every wide primitive
    for n_tokens in [1usize, 3, 7, 33] {
        let mut r = Rng::new(43 + n_tokens as u64);
        let (h, hk, dh) = (4usize, 2usize, 16usize);
        let k = Tensor::randn(&[hk, 64, dh], &mut r);
        let v = Tensor::randn(&[hk, 64, dh], &mut r);
        let q = Tensor::randn(&[h, dh], &mut r);
        let kv = TensorKv { k: &k, v: &v, n_tokens, block: 32 };
        let sel = Selection::decode_full(h, kv.n_blocks());
        let oracle = dense_decode_attention_reference(&q, &kv);
        for arm in ARMS {
            let out = sparse_decode_attention_with(arm, &q, &kv, &sel);
            let d = max_abs_diff(&out, &oracle);
            assert!(d < 1e-5, "{}: n_tokens={n_tokens} deviates by {d}", arm_label(arm));
        }
    }
}

#[test]
fn verify_staircase_matches_oracle_on_both_arms() {
    // γ-wide verify rows whose causal widths straddle a block boundary
    let mut r = Rng::new(47);
    let (g_rows, h, hk, dh, block, base) = (4usize, 2usize, 1usize, 8usize, 16usize, 15usize);
    let q = Tensor::randn(&[g_rows, h, dh], &mut r);
    let k = Tensor::randn(&[hk, 64, dh], &mut r);
    let v = Tensor::randn(&[hk, 64, dh], &mut r);
    let kv = TensorKv { k: &k, v: &v, n_tokens: base + g_rows - 1, block };
    let sel = Selection::verify_full(h, g_rows, kv.n_blocks());
    let want = dense_verify_attention_reference(&q, &kv, base);
    for arm in ARMS {
        let got = sparse_verify_attention_with(arm, &q, &kv, &sel, base);
        let d = max_abs_diff(&got, &want);
        assert!(d < 1e-5, "{}: verify staircase deviates by {d}", arm_label(arm));
    }
}

#[test]
fn extreme_score_magnitudes_stay_finite_on_both_arms() {
    // ±1e4-scale q/k drive raw scores far past the exp range; the online
    // softmax max-shift must keep both arms finite and in agreement
    let mut r = Rng::new(53);
    let (h, dh) = (2usize, 8usize);
    let mut q = Tensor::randn(&[h, dh], &mut r);
    let mut k = Tensor::randn(&[h, 40, dh], &mut r);
    let v = Tensor::randn(&[h, 40, dh], &mut r);
    for x in q.data.iter_mut() {
        *x *= 1e4;
    }
    for x in k.data.iter_mut() {
        *x *= 1e4;
    }
    let kv = TensorKv { k: &k, v: &v, n_tokens: 40, block: 16 };
    let sel = Selection::decode_full(h, kv.n_blocks());
    let outs: Vec<Vec<f32>> =
        ARMS.iter().map(|&a| sparse_decode_attention_with(a, &q, &kv, &sel)).collect();
    for (arm, out) in ARMS.iter().zip(&outs) {
        assert!(
            out.iter().all(|x| x.is_finite()),
            "{}: overflow leaked a non-finite output",
            arm_label(*arm)
        );
    }
    let d = max_abs_diff(&outs[0], &outs[1]);
    assert!(d < 1e-5, "arms diverge under extreme scores by {d}");
}
