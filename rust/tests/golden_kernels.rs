//! Cross-language golden tests: the pure-rust sparse core must reproduce
//! the python jnp oracles bit-for-bit-ish (f32 tolerance) on the golden
//! vectors exported by `python/compile/aot.py` (artifacts/golden/).
//!
//! Skips (with a note) when artifacts are absent so `cargo test` stays
//! green pre-`make artifacts`; CI runs it after the artifact build.

use stem::sparse::{
    block_sparse_attention, block_sparse_attention_reference, oam_scores, SelectionBuilder, Tensor,
};
use stem::util::json::Json;

struct Golden {
    block: usize,
    h: usize,
    hk: usize,
    n: usize,
    dh: usize,
    q: Tensor,
    k: Tensor,
    v: Tensor,
    indices: Vec<i64>,
    counts: Vec<i64>,
    attention_out: Vec<f32>,
    oam: Vec<f32>,
}

fn load_golden() -> Option<Golden> {
    let path = stem::artifacts_dir().join("golden/kernels.json");
    let text = std::fs::read_to_string(&path).ok()?;
    let j = Json::parse(&text).ok()?;
    let us = |k: &str| j.get(k).and_then(Json::as_usize).unwrap();
    let fv = |k: &str| -> Vec<f32> {
        j.get(k)
            .and_then(Json::as_arr)
            .unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap() as f32)
            .collect()
    };
    let iv = |k: &str| -> Vec<i64> {
        j.get(k).and_then(Json::as_arr).unwrap().iter().map(|x| x.as_i64().unwrap()).collect()
    };
    let (h, hk, n, dh) = (us("h"), us("hk"), us("n"), us("dh"));
    Some(Golden {
        block: us("block"),
        h,
        hk,
        n,
        dh,
        q: Tensor::from_vec(&[h, n, dh], fv("q")),
        k: Tensor::from_vec(&[hk, n, dh], fv("k")),
        v: Tensor::from_vec(&[hk, n, dh], fv("v")),
        indices: iv("indices"),
        counts: iv("counts"),
        attention_out: fv("attention_out"),
        oam: fv("oam_scores"),
    })
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn rust_block_sparse_matches_python_oracle() {
    let Some(g) = load_golden() else {
        eprintln!("skipping: artifacts/golden/kernels.json missing (run `make artifacts`)");
        return;
    };
    let nblk = g.n / g.block;
    // python exports fixed-width rows: CSR keeps them as selected prefix
    // + interface padding under per-row counts
    let mut b = SelectionBuilder::with_capacity(g.h, nblk, g.h * nblk * nblk);
    for h in 0..g.h {
        for i in 0..nblk {
            let row: Vec<u32> = (0..nblk)
                .map(|t| g.indices[(h * nblk + i) * nblk + t] as u32)
                .collect();
            b.push_row(&row, g.counts[h * nblk + i] as u32);
        }
    }
    let sel = b.finish();
    sel.validate().expect("golden selection must satisfy kernel invariants");
    let out = block_sparse_attention(&g.q, &g.k, &g.v, &sel, g.block);
    let d = max_abs_diff(&out.data, &g.attention_out);
    assert!(d < 2e-4, "rust block-sparse deviates from jnp oracle: {d}");
    let reference = block_sparse_attention_reference(&g.q, &g.k, &g.v, &sel, g.block);
    let dr = max_abs_diff(&reference.data, &g.attention_out);
    assert!(dr < 2e-4, "rust reference block-sparse deviates from jnp oracle: {dr}");
    let _ = g.hk;
}

#[test]
fn rust_oam_scores_match_python_oracle() {
    let Some(g) = load_golden() else {
        eprintln!("skipping: artifacts missing");
        return;
    };
    // golden emitted with beta=0.2, stride=16 (aot.py export_goldens)
    let scores = oam_scores(&g.q, &g.k, &g.v, g.block, 16, 0.2);
    let nblk = g.n / g.block;
    let mut worst = 0f32;
    for h in 0..g.h {
        for i in 0..nblk {
            for j in 0..=i {
                let want = g.oam[(h * nblk + i) * nblk + j];
                let got = scores.at3(h, i, j);
                worst = worst.max((want - got).abs());
            }
        }
    }
    assert!(worst < 2e-4, "rust OAM deviates from jnp oracle: {worst}");
}
