//! Decode-equivalence property suite for speculative decode (ISSUE 5).
//!
//! The hard contract: speculative draft/verify decode must emit
//! *exactly* — byte-for-byte, not within 1e-5 — the token stream that
//! non-speculative greedy decode under the same serving policy emits,
//! for random prompts, random policies, γ ∈ 1..=6, and across `fork()`
//! siblings; the session state afterwards (context length, last token,
//! step counter, dense/budget accounting) must be indistinguishable
//! too. Failures shrink to a minimal counterexample via `util::prop`.
//!
//! The suite also pins the batched multi-query verify kernel against the
//! dense single-query oracle at 1e-5 (degenerate one-token rows, γ
//! exceeding the base context, page-boundary-straddling tails) and
//! checks the rollback invariants of `truncate_tail` under forked tails
//! (no sibling page ever freed, freed-page log always drained — pool
//! invariants + zero residency after teardown).
//!
//! Every decode property runs once per decode backend — the in-process
//! `TinyLm` projection core and the compiled-module `EngineBackend`
//! served by the synthetic engine — and the equivalence contract is
//! byte-exact *per backend* (the two backends emit different streams
//! from each other; each must agree with its own sequential twin).
//!
//! Artifact-free; CI runs it under `cargo test --release` in a
//! dedicated `spec-equivalence` job.

use std::sync::Arc;

use stem::coordinator::kv_cache::KvConfig;
use stem::decode::{
    DecodeBackend, DecodePolicy, DecodeSession, EngineBackend, SharedKv, TinyLm,
};
use stem::model::vocab;
use stem::runtime::SyntheticEngine;
use stem::sparse::{
    decode_block_scores, dense_verify_attention_reference, select_decode,
    sparse_decode_attention, sparse_verify_attention, KvPrefix, Selection, SelectionBuilder,
    Tensor, TensorKv,
};
use stem::util::prop::forall;
use stem::util::rng::Rng;

const H: usize = 4;
const HK: usize = 2;
const DH: usize = 16;

fn pool(pages: usize, page_tokens: usize) -> Arc<SharedKv> {
    SharedKv::new(KvConfig { total_pages: pages, page_tokens }, HK, DH)
}

/// The decode backends every property must hold for, independently.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Backend {
    Tiny,
    Engine,
}
const BACKENDS: [Backend; 2] = [Backend::Tiny, Backend::Engine];

fn model_for(b: Backend) -> Arc<dyn DecodeBackend> {
    match b {
        Backend::Tiny => Arc::new(TinyLm::new(0xBEEF, H, HK, DH, vocab::VOCAB_SIZE)),
        Backend::Engine => {
            // compiled per-step decode over the synthetic engine at the
            // suite geometry; one bucket comfortably covers every
            // prompt + stream these properties generate
            let mut m = SyntheticEngine::tiny_model();
            m.n_heads = H;
            m.n_kv_heads = HK;
            m.d_head = DH;
            m.d_model = H * DH;
            let engine = Arc::new(SyntheticEngine::with_model(m, &[512]));
            Arc::new(EngineBackend::new(engine, "base").expect("synthetic decode modules"))
        }
    }
}

fn prompt_from(seed: u64, len: usize) -> Vec<i32> {
    let mut r = Rng::new(seed.wrapping_mul(2654435761).wrapping_add(1));
    let mut p = vec![vocab::BOS];
    p.extend((1..len.max(1)).map(|_| vocab::WORD0 + r.below(64) as i32));
    p
}

/// Serving disciplines the properties cycle through: always-dense,
/// the default mixed policy, aggressive always-sparse, and a sparse
/// policy with wide forced sets + fast decay.
fn policy_for(knob: usize, gamma: usize) -> DecodePolicy {
    let base = match knob % 4 {
        0 => DecodePolicy::dense(),
        1 => DecodePolicy::default(),
        2 => DecodePolicy {
            dense_below: 0,
            k_start: 4.0,
            min_blocks: 2,
            recent_blocks: 1,
            ..Default::default()
        },
        _ => DecodePolicy {
            dense_below: 48,
            k_start: 6.0,
            sink_blocks: 2,
            recent_blocks: 2,
            mu: 0.5,
            horizon: 8,
            ..Default::default()
        },
    };
    DecodePolicy { spec_gamma: gamma, ..base }
}

/// Everything an emitted stream must agree on, bit for bit. The budget
/// sum is compared through its f64 bits: speculative accounting adds the
/// same plan fractions in the same order, so even the floats must match.
#[derive(Debug, PartialEq, Eq)]
struct StreamFingerprint {
    tokens: Vec<i32>,
    n_ctx: usize,
    last_token: i32,
    steps: usize,
    dense_steps: usize,
    budget_bits: u64,
}

fn run_once(
    backend: Backend,
    policy: DecodePolicy,
    prompt: &[i32],
    max_new: usize,
    page_tokens: usize,
) -> Result<StreamFingerprint, String> {
    let kv = pool(512, page_tokens);
    let mut s = DecodeSession::new(Arc::clone(&kv), model_for(backend), policy, 1)
        .map_err(|e| format!("session: {e}"))?;
    s.prefill(prompt).map_err(|e| format!("prefill: {e}"))?;
    let st = s.generate(max_new, None, |_| true).map_err(|e| format!("generate: {e}"))?;
    let fp = StreamFingerprint {
        tokens: st.tokens,
        n_ctx: s.n_ctx(),
        last_token: s.last_token(),
        steps: s.steps(),
        dense_steps: s.dense_steps(),
        budget_bits: (s.mean_budget_fraction() * s.steps().max(1) as f64).to_bits(),
    };
    kv.pool().map_err(|e| format!("pool: {e}"))?.check_invariants()?;
    drop(s);
    if kv.pool().map_err(|e| format!("pool: {e}"))?.used_pages() != 0 {
        return Err("session drop leaked pages".into());
    }
    if kv.pages_resident() != 0 {
        return Err("session drop leaked slabs".into());
    }
    Ok(fp)
}

#[test]
fn prop_spec_stream_equals_sequential_exactly() {
    forall(
        0xA11CE,
        24,
        |r: &mut Rng| {
            (
                r.below(120) as usize + 1, // prompt length
                r.below(6) as usize + 1,   // gamma 1..=6
                r.below(4) as usize,       // serving-policy knob
                r.below(18) as usize + 3,  // max_new 3..=20
                r.below(2) == 0,           // small (16) vs larger (32) pages
            )
        },
        |&(plen, gamma, knob, max_new, small_pages)| {
            let pt = if small_pages { 16 } else { 32 };
            let prompt = prompt_from(plen as u64, plen);
            for backend in BACKENDS {
                let seq = run_once(backend, policy_for(knob, 0), &prompt, max_new, pt)?;
                let spec = run_once(backend, policy_for(knob, gamma), &prompt, max_new, pt)?;
                if seq != spec {
                    return Err(format!(
                        "[{backend:?}] spec(γ={gamma}) diverged from sequential\n  seq:  {seq:?}\n  spec: {spec:?}"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_spec_equals_sequential_across_fork_siblings() {
    forall(
        0xF0CA,
        12,
        |r: &mut Rng| {
            (
                r.below(90) as usize + 8, // prompt length
                r.below(6) as usize + 1,  // gamma 1..=6
                r.below(4) as usize,      // serving-policy knob
                r.below(3) as usize + 2,  // fanout 2..=4
            )
        },
        |&(plen, gamma, knob, fanout)| {
            for backend in BACKENDS {
                fork_siblings_case(backend, plen, gamma, knob, fanout)?;
            }
            Ok(())
        },
    );
}

fn fork_siblings_case(
    backend: Backend,
    plen: usize,
    gamma: usize,
    knob: usize,
    fanout: usize,
) -> Result<(), String> {
    let (pt, max_new) = (16usize, 12usize);
    let prompt = prompt_from(plen as u64 ^ 0x51b1, plen);
    let kv = pool(1024, pt);
    let m = model_for(backend);
    let mut root = DecodeSession::new(Arc::clone(&kv), Arc::clone(&m), policy_for(knob, 0), 1)
        .map_err(|e| format!("root: {e}"))?;
    root.prefill(&prompt).map_err(|e| format!("root prefill: {e}"))?;
    // alternate speculative / sequential siblings over one shared
    // refcounted prefix; all stay alive so CoW isolation is live
    let mut branches = Vec::with_capacity(fanout);
    let mut streams = Vec::with_capacity(fanout);
    for i in 0..fanout {
        let mut b = root.fork(10 + i as u64).map_err(|e| format!("fork {i}: {e}"))?;
        b.set_policy(policy_for(knob, if i % 2 == 0 { gamma } else { 0 }));
        let steer = vocab::WORD0 + i as i32;
        b.prefill(&[steer]).map_err(|e| format!("steer {i}: {e}"))?;
        let st = b.generate(max_new, None, |_| true).map_err(|e| format!("gen {i}: {e}"))?;
        streams.push(st.tokens);
        branches.push(b);
    }
    kv.pool().map_err(|e| format!("pool: {e}"))?.check_invariants()?;
    // every sibling — speculative or not — must match a fresh
    // independent sequential session over (prompt + its steer)
    for (i, stream) in streams.iter().enumerate() {
        let mut full = prompt.clone();
        full.push(vocab::WORD0 + i as i32);
        let want = run_once(backend, policy_for(knob, 0), &full, max_new, pt)?;
        if stream != &want.tokens {
            return Err(format!(
                "[{backend:?}] sibling {i} (spec={}) diverged from its independent twin:\n  got:  {stream:?}\n  want: {:?}",
                i % 2 == 0,
                want.tokens
            ));
        }
    }
    // speculative siblings must never leak into the shared root
    let root_stream =
        root.generate(6, None, |_| true).map_err(|e| format!("root gen: {e}"))?.tokens;
    let control = run_once(backend, policy_for(knob, 0), &prompt, 6, pt)?;
    if root_stream != control.tokens {
        return Err(format!("[{backend:?}] speculative siblings leaked into the root"));
    }
    // rollback invariant: tearing everything down frees every
    // page and slab (drafted overshoot included)
    drop(branches);
    drop(root);
    if kv.pool().map_err(|e| format!("pool: {e}"))?.used_pages() != 0 {
        return Err("teardown leaked pool pages".into());
    }
    if kv.pages_resident() != 0 {
        return Err("teardown leaked slab payloads".into());
    }
    Ok(())
}

#[test]
fn spec_stop_token_trims_exactly_like_sequential() {
    // pick a token the sequential stream actually emits mid-way and use
    // it as the stop token in both modes: streams and session state must
    // still agree exactly — independently for each decode backend (each
    // backend emits its own stream, so each picks its own stop token)
    let prompt = prompt_from(99, 60);
    for backend in BACKENDS {
        let seq_full = run_once(backend, policy_for(1, 0), &prompt, 16, 16).unwrap();
        assert!(seq_full.tokens.len() >= 6, "need a few tokens to pick a stop from");
        let stop = seq_full.tokens[seq_full.tokens.len() / 2];
        let run_stop = |gamma: usize| {
            let kv = pool(512, 16);
            let mut s =
                DecodeSession::new(Arc::clone(&kv), model_for(backend), policy_for(1, gamma), 1)
                    .unwrap();
            s.prefill(&prompt).unwrap();
            let st = s.generate(16, Some(stop), |_| true).unwrap();
            (st.tokens, s.n_ctx(), s.last_token(), s.steps())
        };
        let want = run_stop(0);
        assert_eq!(
            want.0.last(),
            Some(&stop),
            "[{backend:?}] sequential run must stop on the stop token"
        );
        for gamma in 1..=6 {
            assert_eq!(
                run_stop(gamma),
                want,
                "[{backend:?}] gamma={gamma}: stop-token trim diverged"
            );
        }
    }
}

#[test]
fn prop_verify_kernel_matches_dense_oracle_across_degenerate_shapes() {
    // satellite: the batched verify kernel vs the scalar per-position
    // oracle at 1e-5 — one-token rows, γ > base context, tails
    // straddling page boundaries, blocks of several sizes
    forall(
        0x5EED,
        40,
        |r: &mut Rng| {
            (
                r.below(200) as usize + 1, // base width of position 0
                r.below(7) as usize + 1,   // G positions (up to γ+1 = 7)
                r.below(3) as usize,       // block-size selector
                r.below(1 << 16),          // data seed (u64)
            )
        },
        |&(base, g_rows, bsel, seed)| {
            if base == 0 || g_rows == 0 {
                return Ok(()); // shrinker floor: vacuous
            }
            let block = [16usize, 32, 48][bsel % 3];
            let n = base + g_rows - 1;
            let mut r = Rng::new(seed ^ 0xD1CE);
            let q = Tensor::randn(&[g_rows, H, DH], &mut r);
            let k = Tensor::randn(&[HK, n, DH], &mut r);
            let v = Tensor::randn(&[HK, n, DH], &mut r);
            let kv = TensorKv { k: &k, v: &v, n_tokens: n, block };
            let nblk = kv.n_blocks();
            // full (dense-plan) verify selection vs the oracle
            let sel = Selection::verify_full(H, g_rows, nblk);
            sel.validate_verify(nblk)?;
            let got = sparse_verify_attention(&q, &kv, &sel, base);
            let want = dense_verify_attention_reference(&q, &kv, base);
            let d = got.iter().zip(&want).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
            if d >= 1e-5 {
                return Err(format!(
                    "verify kernel deviates from oracle by {d} (base={base}, G={g_rows}, block={block})"
                ));
            }
            // sparse per-position selections: the batched walk must be
            // BITWISE equal to independent single-query passes
            let budget = (nblk / 2).max(1);
            let mut row_sels = Vec::with_capacity(g_rows);
            for g in 0..g_rows {
                let pre = KvPrefix::new(&kv, base + g);
                let qg =
                    Tensor::from_vec(&[H, DH], q.data[g * H * DH..(g + 1) * H * DH].to_vec());
                let scores = decode_block_scores(&qg, &pre, 4, 0.2);
                row_sels.push(select_decode(&scores, budget, 1, 1));
            }
            let mut b = SelectionBuilder::new(H, g_rows);
            for hh in 0..H {
                for s in &row_sels {
                    let row = s.selected(hh, 0);
                    b.push_row(row, row.len() as u32);
                }
            }
            let sparse_sel = b.finish();
            sparse_sel.validate_verify(nblk)?;
            let got = sparse_verify_attention(&q, &kv, &sparse_sel, base);
            for g in 0..g_rows {
                let pre = KvPrefix::new(&kv, base + g);
                let qg =
                    Tensor::from_vec(&[H, DH], q.data[g * H * DH..(g + 1) * H * DH].to_vec());
                let want = sparse_decode_attention(&qg, &pre, &row_sels[g]);
                if got[g * H * DH..(g + 1) * H * DH] != want[..] {
                    return Err(format!(
                        "verify row {g} not bitwise-equal to its single-query pass (base={base}, G={g_rows}, block={block})"
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_truncate_tail_rollback_invariants_under_forks() {
    // satellite: random fork/append/truncate workloads — a truncate
    // never frees a page a sibling still references, the freed-page log
    // drains into slab GC, and pool invariants hold throughout
    forall(
        0x70C4,
        30,
        |r: &mut Rng| {
            (0..24)
                .map(|_| (r.below(4) as usize, r.below(40) as usize + 1))
                .collect::<Vec<(usize, usize)>>()
        },
        |ops| {
            let pt = 8usize;
            let kv = pool(64, pt);
            let mut next = 1u64;
            let mut live: Vec<(u64, usize)> = vec![]; // (seq, n_tokens)
            kv.allocate(next, 0).map_err(|e| format!("alloc: {e}"))?;
            live.push((next, 0));
            for &(op, size) in ops {
                match op {
                    // append + write the new slots (so slab GC has
                    // payloads to collect)
                    0 => {
                        if let Some(e) = live.last_mut() {
                            if let Ok(app) = kv.append_tokens(e.0, size) {
                                for &p in app.cow.iter().map(|(_, n)| n).chain(app.grown.iter())
                                {
                                    let rows = vec![p as f32; HK * DH];
                                    kv.write_token(p, 0, &rows, &rows)
                                        .map_err(|x| format!("write: {x}"))?;
                                }
                                e.1 += size;
                            }
                        }
                    }
                    // fork the most recent live sequence
                    1 => {
                        if let Some(&(src, n)) = live.last() {
                            next += 1;
                            if kv.fork(src, next).is_ok() {
                                live.push((next, n));
                            }
                        }
                    }
                    // truncate a live sequence's tail
                    2 => {
                        if !live.is_empty() {
                            let i = size % live.len();
                            let (seq, n) = live[i];
                            let target = n.saturating_sub(size);
                            kv.truncate_tail(seq, target)
                                .map_err(|e| format!("truncate: {e}"))?;
                            live[i].1 = target;
                        }
                    }
                    // drop a sequence
                    _ => {
                        if live.len() > 1 {
                            let i = size % live.len();
                            let (seq, _) = live.remove(i);
                            kv.drop_seq(seq).map_err(|e| format!("drop: {e}"))?;
                        }
                    }
                }
                let pool = kv.pool().map_err(|e| format!("pool: {e}"))?;
                pool.check_invariants()?;
                // every live sequence still has a consistent table: a
                // truncate that freed a sibling's page would break this
                for &(seq, n) in &live {
                    match pool.page_table(seq) {
                        Some(t) if t.len() == n.div_ceil(pt) => {}
                        Some(t) => {
                            return Err(format!(
                                "seq {seq}: table {} pages for {n} tokens",
                                t.len()
                            ))
                        }
                        None => return Err(format!("seq {seq} vanished")),
                    }
                }
                // slab residency never exceeds referenced pages (drained
                // freed-page log ⇒ no zombie payloads)
                let used = pool.used_pages();
                drop(pool);
                if kv.pages_resident() > used {
                    return Err(format!(
                        "zombie slabs: {} resident > {} used",
                        kv.pages_resident(),
                        used
                    ));
                }
            }
            for (seq, _) in live.drain(..) {
                let _ = kv.release(seq);
                kv.drop_seq(seq).map_err(|e| format!("final drop: {e}"))?;
            }
            if kv.pool().map_err(|e| format!("pool: {e}"))?.used_pages() != 0
                || kv.pages_resident() != 0
            {
                return Err("teardown leaked pages or slabs".into());
            }
            Ok(())
        },
    );
}
