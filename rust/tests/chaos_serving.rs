//! Chaos suite: drives the full coordinator (synthetic backend, no
//! artifacts) through randomized request mixes — prefills, fan-out
//! generations over multi-chunk prompt ingests, shared and
//! partially-shared prefixes, speculative decode, tiny deadlines,
//! abandoned clients — under a seeded [`FaultPlan`] injecting
//! KV-allocation failures, engine errors, decode-step panics,
//! chunk-boundary ingest panics and worker stalls. Invariants checked:
//!
//! * every submitted request reaches exactly one terminal outcome
//!   (success, typed shed, typed error, or typed partial) — nothing
//!   hangs past a generous timeout;
//! * after a full drain, admission counters and the KV pool balance
//!   back to zero — no leaked pages, slabs or permits;
//! * workers survive injected panics (the pool keeps serving, the
//!   panic surfaces as one request's [`ServeError::WorkerPanic`]);
//! * no poisoned lock escapes to the caller as a panic.
//!
//! `STEM_FAULTS` (the CI chaos matrix) overrides the plan; otherwise
//! three built-in seeds run. Invariant failures dump the coordinator's
//! flight-recorder ring (`stem::obs::trace`) headed by a `STEM_FAULTS`
//! replay line, so a red run ships its own event history.

use std::sync::atomic::Ordering;
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

use stem::coordinator::admission::AdmissionConfig;
use stem::coordinator::{
    Coordinator, CoordinatorConfig, Finish, GenerateTicket, Method, PrefillResponse, ServeError,
};
use stem::decode::DecodePolicy;
use stem::runtime::{PrefillBackend, SyntheticEngine};
use stem::util::fault::{FaultPlan, FaultPoint};
use stem::util::rng::Rng;

/// Generous terminal-outcome timeout: the suite runs release-mode in
/// CI; anything near this bound is a hang, not slowness.
const TERMINAL: Duration = Duration::from_secs(60);

fn default_plan(seed: u64) -> FaultPlan {
    FaultPlan::new(seed)
        .with_rate(FaultPoint::KvAlloc, 0.08)
        .with_rate(FaultPoint::EngineExec, 0.06)
        .with_rate(FaultPoint::DecodeStep, 0.05)
        .with_rate(FaultPoint::WorkerStall, 0.05)
        .with_rate(FaultPoint::IngestChunk, 0.06)
        .with_stall(Duration::from_micros(200))
}

fn chaos_coordinator(plan: &Arc<FaultPlan>) -> Coordinator {
    let engine: Arc<dyn PrefillBackend> = Arc::new(SyntheticEngine::new(&[128, 256]));
    Coordinator::with_backend(
        engine,
        CoordinatorConfig {
            workers: 4,
            kv_pages: 256,
            // small chunks so the wave's prompt bases span several
            // ingest chunks — chunk-boundary faults, sheds and cancels
            // all get real boundaries to land on
            chunk_tokens: 48,
            admission: AdmissionConfig {
                max_tokens: 16 * 1024,
                max_requests: 64,
                ..Default::default()
            },
            faults: Some(Arc::clone(plan)),
            ..Default::default()
        },
    )
}

/// Tally of terminal outcomes across one run (every count is a request
/// or branch that *did* terminate — hangs panic before reaching here).
#[derive(Debug, Default)]
struct Outcomes {
    prefill_ok: usize,
    prefill_err: usize,
    gen_complete: usize,
    gen_cancelled: usize,
    gen_deadline: usize,
    gen_err: usize,
    shed_at_submit: usize,
    abandoned: usize,
}

/// One wave of randomized traffic; returns the in-flight channels so
/// the caller collects every terminal outcome.
fn one_wave(
    coord: &Coordinator,
    rng: &mut Rng,
    outcomes: &mut Outcomes,
) -> (Vec<mpsc::Receiver<anyhow::Result<PrefillResponse>>>, Vec<GenerateTicket>) {
    // shared prompt bases: reused across the wave so holder reuse,
    // radix partial hits (base + divergent suffix) and refills all
    // fire; long enough (96/136/176 tokens) that every fill spans
    // several 48-token ingest chunks
    let bases: Vec<Vec<i32>> = (0..3)
        .map(|b| (0..96 + 40 * b).map(|i| 16 + ((i + 5 * b) % 64) as i32).collect())
        .collect();
    let mut prefill_rxs = Vec::new();
    let mut tickets = Vec::new();
    for _ in 0..18 {
        match rng.below(4) {
            // prefill through the batcher + worker pool
            0 => {
                let n = 16 + rng.below(200) as usize;
                let ids: Vec<i32> = (0..n).map(|i| 16 + (i % 64) as i32).collect();
                let method = Method::Stem { k_start: 6.0, mu: 0.7, beta: 0.2 };
                let deadline = (rng.below(4) == 0)
                    .then(|| Instant::now() + Duration::from_micros(rng.below(1500)));
                match coord.submit_with_deadline("base", method, ids, false, deadline) {
                    Ok(rx) => prefill_rxs.push(rx),
                    Err(_) => outcomes.shed_at_submit += 1,
                }
            }
            // fan-out generation over a shared base (holder reuse)
            1 | 2 => {
                let mut prompt = bases[rng.below(3) as usize].clone();
                if rng.below(2) == 0 {
                    // divergent suffix: radix-mode partial hit
                    prompt.extend((0..rng.below(12)).map(|j| 40 + (j % 32) as i32));
                }
                let policy =
                    DecodePolicy { spec_gamma: rng.below(4) as usize, ..Default::default() };
                let fanout = 1 + rng.below(4) as usize;
                let max_new = 1 + rng.below(24) as usize;
                let deadline = (rng.below(5) == 0)
                    .then(|| Instant::now() + Duration::from_micros(rng.below(2000)));
                match coord.submit_generate_tickets(prompt, max_new, policy, fanout, deadline) {
                    Ok(ts) => {
                        for t in ts {
                            // some clients walk away without reading
                            if rng.below(6) == 0 {
                                outcomes.abandoned += 1;
                                drop(t);
                            } else {
                                tickets.push(t);
                            }
                        }
                    }
                    Err(_) => outcomes.shed_at_submit += 1,
                }
            }
            // single generation, occasionally cancelled mid-flight
            _ => {
                let prompt: Vec<i32> = (0..8 + rng.below(24)).map(|i| 20 + (i % 40) as i32).collect();
                match coord.submit_generate_tickets(
                    prompt,
                    4 + rng.below(40) as usize,
                    DecodePolicy::default(),
                    1,
                    None,
                ) {
                    Ok(mut ts) => {
                        let t = ts.pop().expect("fanout 1");
                        if rng.below(3) == 0 {
                            t.cancel_handle().cancel();
                        }
                        tickets.push(t);
                    }
                    Err(_) => outcomes.shed_at_submit += 1,
                }
            }
        }
    }
    (prefill_rxs, tickets)
}

fn collect(
    seed: u64,
    outcomes: &mut Outcomes,
    prefill_rxs: Vec<mpsc::Receiver<anyhow::Result<PrefillResponse>>>,
    tickets: Vec<GenerateTicket>,
) {
    for rx in prefill_rxs {
        match rx.recv_timeout(TERMINAL) {
            Ok(Ok(_)) => outcomes.prefill_ok += 1,
            Ok(Err(_)) => outcomes.prefill_err += 1,
            Err(_) => panic!("seed {seed}: prefill never reached a terminal outcome"),
        }
    }
    for mut t in tickets {
        match t.recv_timeout(TERMINAL) {
            Ok(resp) => match resp.finish {
                Finish::Complete => outcomes.gen_complete += 1,
                Finish::Cancelled => outcomes.gen_cancelled += 1,
                Finish::DeadlineExceeded => outcomes.gen_deadline += 1,
            },
            Err(e) if e.to_string().contains("timed out") => {
                panic!("seed {seed}: generation never reached a terminal outcome")
            }
            Err(_) => outcomes.gen_err += 1,
        }
    }
}

fn chaos_run(plan: Arc<FaultPlan>) {
    let seed = plan.seed();
    let coord = chaos_coordinator(&plan);
    let kv = Arc::clone(coord.shared_kv());
    let admission = Arc::clone(coord.admission());
    let metrics = Arc::clone(&coord.metrics);

    let mut rng = Rng::new(seed ^ 0xC0FF_EE00);
    let mut outcomes = Outcomes::default();
    // any invariant failure in the live phase prints the flight-recorder
    // ring — with the STEM_FAULTS replay line — before re-panicking, so
    // a red chaos run ships the event history needed to replay it
    let live = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        // bounded extra waves until the run has demonstrably survived at
        // least one injected panic and one injected KV-allocation failure
        let mut waves = 0usize;
        loop {
            waves += 1;
            let (rxs, tickets) = one_wave(&coord, &mut rng, &mut outcomes);
            collect(seed, &mut outcomes, rxs, tickets);
            let survived_panic = metrics.worker_panics.load(Ordering::Relaxed) >= 1;
            let saw_kv_fault = plan.injected(FaultPoint::KvAlloc) >= 1;
            if (survived_panic && saw_kv_fault) || waves >= 12 {
                assert!(
                    survived_panic && saw_kv_fault,
                    "seed {seed}: after {waves} waves injected too little chaos \
                     (worker_panics={}, kv_faults={}) — raise rates or waves",
                    metrics.worker_panics.load(Ordering::Relaxed),
                    plan.injected(FaultPoint::KvAlloc),
                );
                break;
            }
        }

        // a worker that ate an injected panic must still serve: drive a
        // clean request end to end (faults stay armed, so individual
        // attempts may legitimately eat another injection — retry a few)
        let survived = (0..20).any(|_| {
            matches!(
                coord.generate_blocking(vec![1, 20, 21, 22], 4, DecodePolicy::default()),
                Ok(resp) if resp.finish == Finish::Complete
            )
        });
        assert!(survived, "seed {seed}: worker pool did not keep serving after injected panics");
    }));
    if let Err(payload) = live {
        if let Some(rec) = coord.flight_recorder() {
            eprintln!("{}", rec.render_failure_dump(None, Some(&plan.spec_string())));
        }
        std::panic::resume_unwind(payload);
    }

    // render the ring before shutdown so the post-drain leak assertions
    // below can still print it on failure
    let dump = coord
        .flight_recorder()
        .map(|rec| rec.render_failure_dump(None, Some(&plan.spec_string())));

    // full drain: shutdown joins the dispatcher only after every queued
    // batch and in-flight decode completed
    drop(coord);
    let drained = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        assert_eq!(
            admission.outstanding(),
            (0, 0),
            "seed {seed}: admission counters leaked (outcomes: {outcomes:?})"
        );
        let (used, _, _) = kv.occupancy();
        assert_eq!(used, 0, "seed {seed}: KV pages leaked (outcomes: {outcomes:?})");
        assert_eq!(kv.pages_resident(), 0, "seed {seed}: KV slabs leaked");
        assert!(
            admission.outstanding_work_ns() < 1.0,
            "seed {seed}: admission work estimate leaked"
        );

        let terminal = outcomes.prefill_ok
            + outcomes.prefill_err
            + outcomes.gen_complete
            + outcomes.gen_cancelled
            + outcomes.gen_deadline
            + outcomes.gen_err;
        assert!(terminal > 0, "seed {seed}: the run exercised nothing");
        // typed worker-panic errors must be observable as such, not as
        // hangs or process aborts — count them via the metric (some
        // panics land in holder fills, which surface on whichever branch
        // was waiting)
        assert!(
            metrics.worker_panics.load(Ordering::Relaxed) >= 1,
            "seed {seed}: no injected panic was isolated"
        );
        // downcast sanity on one deliberately-typed path: an expired
        // deadline submitted now must come back as ServeError
        let coord2 = chaos_coordinator(&plan);
        let past = Instant::now() - Duration::from_millis(5);
        let mut ts = coord2
            .submit_generate_tickets(vec![1, 2, 3], 4, DecodePolicy::default(), 1, Some(past))
            .expect("submit");
        let err = ts
            .pop()
            .expect("one branch")
            .recv_timeout(TERMINAL)
            .expect_err("expired deadline must shed");
        assert_eq!(
            err.downcast_ref::<ServeError>(),
            Some(&ServeError::DeadlineExceeded),
            "seed {seed}: shed was not typed"
        );
    }));
    if let Err(payload) = drained {
        if let Some(d) = dump {
            eprintln!("{d}");
        }
        std::panic::resume_unwind(payload);
    }
}

#[test]
fn chaos_every_request_terminal_and_everything_balances() {
    // CI matrix: one plan from STEM_FAULTS; local runs sweep three seeds
    match FaultPlan::from_env() {
        Some(plan) => chaos_run(Arc::new(plan)),
        None => {
            for seed in [11, 23, 47] {
                chaos_run(Arc::new(default_plan(seed)));
            }
        }
    }
}

/// Chunk-boundary chaos: long prompts ingested in 48-token chunks under
/// a plan that panics ingest chunks outright, plus KV-allocation
/// failures, stalls, tight deadlines and client cancellations landing
/// mid-ingest. Every branch must reach a typed terminal outcome, the
/// injected chunk panics must be isolated (not aborts), and after a
/// full drain holders, pages and admission must balance back to zero.
#[test]
fn chunked_ingest_faults_and_cancels_unwind_at_chunk_boundaries() {
    let plan = Arc::new(
        FaultPlan::new(0x1A67)
            .with_rate(FaultPoint::IngestChunk, 0.25)
            .with_rate(FaultPoint::KvAlloc, 0.05)
            .with_rate(FaultPoint::WorkerStall, 0.10)
            .with_stall(Duration::from_micros(200)),
    );
    let coord = chaos_coordinator(&plan);
    let kv = Arc::clone(coord.shared_kv());
    let admission = Arc::clone(coord.admission());
    let metrics = Arc::clone(&coord.metrics);
    let mut rng = Rng::new(0xFEED);
    let (mut terminal, mut cancelled, mut shed) = (0usize, 0usize, 0usize);
    // bounded extra waves until an ingest-chunk fault demonstrably fired
    // and at least one cancellation landed mid-ingest
    for wave in 0..8usize {
        let mut tickets = Vec::new();
        for i in 0..16usize {
            // 2-6 ingest chunks at chunk_tokens = 48
            let n = 96 + rng.below(200) as usize;
            let prompt: Vec<i32> =
                (0..n).map(|j| 16 + ((wave + i * 3 + j) % 64) as i32).collect();
            let deadline = (rng.below(4) == 0)
                .then(|| Instant::now() + Duration::from_micros(500 + rng.below(4000)));
            match coord.submit_generate_tickets(
                prompt,
                1 + rng.below(8) as usize,
                DecodePolicy::default(),
                1 + rng.below(3) as usize,
                deadline,
            ) {
                Ok(ts) => {
                    for t in ts {
                        if rng.below(4) == 0 {
                            // client walks away mid-ingest; the next
                            // chunk boundary must shed the whole group
                            t.cancel_handle().cancel();
                            cancelled += 1;
                        }
                        tickets.push(t);
                    }
                }
                Err(_) => shed += 1,
            }
        }
        for mut t in tickets {
            match t.recv_timeout(TERMINAL) {
                Ok(_) => terminal += 1,
                Err(e) if e.to_string().contains("timed out") => {
                    panic!("chunked-ingest branch never reached a terminal outcome")
                }
                Err(_) => terminal += 1,
            }
        }
        if wave >= 1 && cancelled >= 1 && plan.injected(FaultPoint::IngestChunk) >= 1 {
            break;
        }
    }
    assert!(terminal > 0, "the run exercised nothing (shed_at_submit={shed})");
    assert!(
        plan.injected(FaultPoint::IngestChunk) >= 1,
        "no ingest-chunk fault fired — raise the rate or wave count"
    );
    assert!(cancelled >= 1, "no cancellation landed mid-ingest");
    assert!(
        metrics.worker_panics.load(Ordering::Relaxed) >= 1,
        "an injected chunk panic was not isolated"
    );
    drop(coord);
    assert_eq!(admission.outstanding(), (0, 0), "admission counters leaked");
    let (used, _, _) = kv.occupancy();
    assert_eq!(used, 0, "KV pages leaked");
    assert_eq!(kv.pages_resident(), 0, "KV slabs leaked");
    assert!(admission.outstanding_work_ns() < 1.0, "admission work estimate leaked");
}
