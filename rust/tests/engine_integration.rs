//! Integration tests over the compiled artifacts: engine execution,
//! python↔rust logits agreement, coordinator request conservation,
//! method/budget behaviour. All tests skip gracefully when artifacts are
//! missing so `cargo test` works pre-`make artifacts` — except the
//! synthetic-backend decode cases at the bottom, which serve
//! `decode_step` modules in-process and run everywhere.

use std::sync::Arc;

use stem::coordinator::{Coordinator, CoordinatorConfig, Method};
use stem::decode::DecodeBackendKind;
use stem::runtime::{Engine, PrefillBackend, SyntheticEngine};
use stem::util::json::Json;

fn engine() -> Option<Arc<Engine>> {
    let dir = stem::artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts missing (run `make artifacts`)");
        return None;
    }
    Some(Arc::new(Engine::new(&dir).expect("engine boots from artifacts")))
}

#[test]
fn dense_prefill_matches_python_golden_logits() {
    let Some(engine) = engine() else { return };
    let dir = stem::artifacts_dir();
    let text = std::fs::read_to_string(dir.join("golden/model_dense_512.json")).unwrap();
    let j = Json::parse(&text).unwrap();
    let ids: Vec<i32> = j
        .get("ids")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|v| v.as_i64().unwrap() as i32)
        .collect();
    let argmax: Vec<i32> = j
        .get("argmax")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .map(|v| v.as_i64().unwrap() as i32)
        .collect();
    let out = engine.prefill("base", "prefill_dense", ids.len(), &ids, &[]).unwrap();
    let mut bad = 0;
    for (p, want) in argmax.iter().enumerate() {
        let row = &out.logits[p * out.vocab..(p + 1) * out.vocab];
        let got =
            row.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0 as i32;
        if got != *want {
            bad += 1;
        }
    }
    assert!(
        (bad as f64) < 0.02 * argmax.len() as f64,
        "XLA-executed logits disagree with python on {bad}/{} positions",
        argmax.len()
    );
}

#[test]
fn stem_budget_scales_with_k_start() {
    let Some(engine) = engine() else { return };
    // 2048 = 32 blocks: wide enough that the forced sink/local floor does
    // not clamp the whole schedule (at 8 blocks every k_start in 2..6
    // collapses to the same floored budget — see EXPERIMENTS.md Table 5).
    let n = 2048;
    let ids: Vec<i32> = (0..n).map(|i| 16 + (i % 64) as i32).collect();
    use stem::runtime::ScalarValue::F32;
    let run = |ks: f32| {
        engine
            .prefill("base", "prefill_stem", n, &ids, &[F32(ks), F32(0.7), F32(0.2)])
            .unwrap()
            .budget_fraction
    };
    let (small, large) = (run(5.0), run(16.0));
    assert!(small < large, "budget must grow with k_start: {small} vs {large}");
    assert!(small > 0.0 && large <= 1.0);
}

#[test]
fn mu_one_beta_zero_is_uniform_sam_superset_of_decay() {
    let Some(engine) = engine() else { return };
    let n = 512;
    let ids: Vec<i32> = (0..n).map(|i| 16 + ((i * 7) % 60) as i32).collect();
    use stem::runtime::ScalarValue::F32;
    let uni =
        engine.prefill("base", "prefill_stem", n, &ids, &[F32(4.0), F32(1.0), F32(0.0)]).unwrap();
    let dec =
        engine.prefill("base", "prefill_stem", n, &ids, &[F32(4.0), F32(0.7), F32(0.0)]).unwrap();
    assert!(
        dec.budget_fraction <= uni.budget_fraction + 1e-6,
        "decay must not exceed uniform at same k_start: {} vs {}",
        dec.budget_fraction,
        uni.budget_fraction
    );
}

#[test]
fn diag_module_exposes_per_layer_hidden() {
    let Some(engine) = engine() else { return };
    let man = engine.manifest().clone();
    let Some(m) = man.modules.iter().find(|m| m.kind == "diag_dense") else {
        eprintln!("skipping: no diag modules");
        return;
    };
    let n = m.n_ctx;
    let ids: Vec<i32> = (0..n).map(|i| 16 + (i % 64) as i32).collect();
    let out = engine.prefill("base", "diag_dense", n, &ids, &[]).unwrap();
    let hidden = out.hidden.expect("diag module returns hidden states");
    assert_eq!(hidden.len(), man.model.n_layers * n * man.model.d_model);
    assert!(hidden.iter().all(|x| x.is_finite()));
}

#[test]
fn coordinator_conserves_requests_across_buckets_and_methods() {
    let Some(engine) = engine() else { return };
    let coord = Arc::new(Coordinator::new(engine, CoordinatorConfig::default()));
    let mk_ids = |n: usize| -> Vec<i32> { (0..n).map(|i| 16 + (i % 50) as i32).collect() };
    let mut rxs = vec![];
    let methods =
        [Method::Dense, Method::Stem { k_start: 4.0, mu: 0.7, beta: 0.2 }, Method::Dense];
    for r in 0..12 {
        let n = [200usize, 512, 700][r % 3];
        let m = methods[r % methods.len()];
        rxs.push(coord.submit("base", m, mk_ids(n), false).unwrap());
    }
    let mut got = 0;
    for rx in rxs {
        let resp = rx.recv().unwrap().unwrap();
        assert!(resp.n_ctx >= resp.n_input);
        assert!(resp.budget_fraction > 0.0 && resp.budget_fraction <= 1.0);
        got += 1;
    }
    assert_eq!(got, 12, "every submitted request must complete exactly once");
    let report = coord.report();
    assert!(report.contains("completed"), "metrics report renders: {report}");
}

#[test]
fn fanout_shares_one_prefix_across_branches_and_requests() {
    use std::sync::atomic::Ordering;
    use stem::decode::DecodePolicy;

    let Some(engine) = engine() else { return };
    let coord = Arc::new(Coordinator::new(engine, CoordinatorConfig::default()));
    let prompt: Vec<i32> = (0..200).map(|i| 16 + (i % 50) as i32).collect();
    let rxs = coord
        .submit_generate_many(prompt.clone(), 8, DecodePolicy::default(), 4)
        .expect("fanout submit admits");
    assert_eq!(rxs.len(), 4);
    let mut streams = vec![];
    for rx in rxs {
        let resp = rx.recv().unwrap().unwrap();
        assert_eq!(resp.n_prompt, prompt.len());
        assert_eq!(resp.steps, resp.tokens.len());
        streams.push(resp.tokens);
    }
    // greedy decode without a divergence suffix: branches must agree
    // (they share one prefix and the same deterministic LM)
    for w in streams.windows(2) {
        assert_eq!(w[0], w[1], "sibling branches must decode identically");
    }
    // one ingest for the whole group, one fork per branch
    assert_eq!(coord.metrics.prefix_misses.load(Ordering::Relaxed), 1);
    assert_eq!(coord.metrics.forks.load(Ordering::Relaxed), 4);
    // a follow-up request with the same prompt rides the cached prefix
    let again = coord.generate_blocking(prompt, 8, DecodePolicy::default()).unwrap();
    assert_eq!(again.tokens, streams[0], "prefix-cache hit must not change the stream");
    assert_eq!(coord.metrics.prefix_misses.load(Ordering::Relaxed), 1, "no re-ingest");
    assert!(coord.metrics.prefix_hits.load(Ordering::Relaxed) >= 1);
    assert_eq!(coord.metrics.forks.load(Ordering::Relaxed), 5);
    let report = coord.report();
    assert!(report.contains("fanout: forks=5"), "{report}");
    assert!(report.contains("cached prefixes: 1"), "{report}");
}

#[test]
fn radix_mode_serves_partial_prefix_hits() {
    use std::sync::atomic::Ordering;
    use stem::coordinator::PrefixMode;
    use stem::decode::DecodePolicy;

    let Some(engine) = engine() else { return };
    // default config = radix prefix matching
    let coord = Arc::new(Coordinator::new(engine, CoordinatorConfig::default()));
    let pt = coord.shared_kv().page_tokens();
    // prompt B shares exactly two pages of tokens with prompt A, then
    // diverges; radix mode must fork the covered pages and ingest only
    // B's suffix
    let a: Vec<i32> = (0..2 * pt + 7).map(|i| 16 + (i % 40) as i32).collect();
    let mut b: Vec<i32> = a[..2 * pt].to_vec();
    b.extend((0..pt).map(|i| 60 + (i % 20) as i32));
    let first = coord.generate_blocking(a, 6, DecodePolicy::default()).unwrap();
    assert!(first.steps >= 1, "generation must produce at least one token");
    let second = coord.generate_blocking(b.clone(), 6, DecodePolicy::default()).unwrap();
    assert_eq!(second.n_prompt, b.len());
    assert_eq!(coord.metrics.prefix_partial_hits.load(Ordering::Relaxed), 1);
    assert!(coord.metrics.covered_token_ratio() > 0.0, "covered-token gauge must move");
    let report = coord.report();
    assert!(report.contains("partial=1"), "{report}");
    assert!(report.contains("cached prefixes: 2"), "{report}");
    // decode parity: the partially-reused continuation must equal a
    // clean full ingest on an exact-mode coordinator
    let Some(engine2) = engine() else { return };
    let control_coord = Arc::new(Coordinator::new(
        engine2,
        CoordinatorConfig { prefix_mode: PrefixMode::Exact, ..Default::default() },
    ));
    let control = control_coord.generate_blocking(b, 6, DecodePolicy::default()).unwrap();
    assert_eq!(
        second.tokens, control.tokens,
        "partial-prefix reuse must not change the decoded stream"
    );
}

#[test]
fn synthetic_backend_serves_the_compiled_decode_lane() {
    // runs without artifacts: the synthetic engine publishes
    // `decode_step` modules per bucket, so the EngineBackend code path —
    // bucket selection, history padding, per-step module execution — is
    // exercised end to end through the coordinator in every CI run
    use stem::decode::DecodePolicy;

    let engine: Arc<dyn PrefillBackend> = Arc::new(SyntheticEngine::new(&[128, 256]));
    let coord = Arc::new(Coordinator::with_backend(
        engine,
        CoordinatorConfig { decode_backend: DecodeBackendKind::Engine, ..Default::default() },
    ));
    assert_eq!(coord.decode_model().name(), "engine");
    let prompt: Vec<i32> = (0..48).map(|i| 16 + (i % 50) as i32).collect();
    let resp = coord.generate_blocking(prompt, 8, DecodePolicy::default()).unwrap();
    assert_eq!(resp.steps, 8, "engine-backed decode must run to completion");
    assert!(coord.report().contains("decode backend: engine"), "{}", coord.report());
    let snap = coord.snapshot();
    assert_eq!(snap.decode_backend, Some("engine"));
}

#[test]
fn real_artifacts_decode_through_compiled_step_modules() {
    // gated twice: on artifacts existing, and on the manifest carrying
    // decode_step modules (artifact sets predating the decode lowering
    // log the fallback instead of failing here)
    let Some(engine) = engine() else { return };
    if !engine.manifest().modules.iter().any(|m| m.kind == "decode_step") {
        eprintln!("skipping: artifacts predate the decode_step lowering (re-run `make artifacts`)");
        return;
    }
    use stem::decode::DecodePolicy;

    let coord = Arc::new(Coordinator::new(
        engine,
        CoordinatorConfig { decode_backend: DecodeBackendKind::Engine, ..Default::default() },
    ));
    assert_eq!(coord.decode_model().name(), "engine");
    let prompt: Vec<i32> = (0..200).map(|i| 16 + (i % 50) as i32).collect();
    let resp = coord.generate_blocking(prompt, 6, DecodePolicy::default()).unwrap();
    assert_eq!(resp.steps, 6);
    assert!(resp.tokens.iter().all(|&t| t >= 0), "decoded tokens must be valid vocab ids");
}

#[test]
fn rejects_oversized_and_unknown() {
    let Some(engine) = engine() else { return };
    let coord = Arc::new(Coordinator::new(engine, CoordinatorConfig::default()));
    // longer than every bucket
    let huge: Vec<i32> = vec![16; 1 << 20];
    assert!(coord.submit("base", Method::Dense, huge, false).is_err());
    // unknown checkpoint surfaces as a response-level error
    let rx = coord.submit("nope", Method::Dense, vec![16; 64], false).unwrap();
    assert!(rx.recv().unwrap().is_err());
}
