//! Paired differential property suite: the Wide SIMD arm must match the
//! scalar oracle at 1e-5 for every vectorized kernel entry point, across
//! lane-hostile shapes — page-straddling `KvPrefix` views, γ-wide verify
//! staircases, sub-lane tails and GQA head fans (ISSUE 10 satellite).
//!
//! Every property passes arms explicitly through the `*_with` variants,
//! so the suite is independent of the process-global dispatch state; the
//! one test that exercises the override (`dispatch_globals_round_trip`)
//! restores it before returning, and no other test here reads
//! `simd::active`. CI runs this binary once per `STEM_SIMD` arm in the
//! release lane alongside `spec_equivalence` (.github/workflows/ci.yml).

use stem::sparse::simd::{self, SimdArm};
use stem::sparse::{
    antidiag_scores_with, block_sparse_attention_with, decode_block_scores_with,
    dense_attention_with, dense_decode_attention_with, oam_scores_with, select_decode,
    select_streaming, sparse_decode_attention_with, sparse_verify_attention_with, KvBlocks,
    KvPrefix, Selection, SelectionBuilder, Tensor, TensorKv,
};
use stem::util::prop::forall;
use stem::util::rng::Rng;

const TOL: f32 = 1e-5;
const S: SimdArm = SimdArm::Scalar;
const W: SimdArm = SimdArm::Wide;

fn maxdiff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "arms must agree on output shape");
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

#[test]
fn prefill_kernels_agree_across_arms() {
    forall(
        61,
        12,
        |r: &mut Rng| {
            (
                r.below(1 << 31),
                1 + r.below(4) as usize, // key blocks of 32: n in 32..=128
                r.below(2) == 0,         // gqa
            )
        },
        |&(seed, nblk, gqa)| {
            let mut rng = Rng::new(seed);
            let (h, dh, block, stride) = (4usize, 24usize, 32usize, 8usize);
            let hk = if gqa { 2 } else { 4 };
            let n = nblk * block;
            let q = Tensor::randn(&[h, n, dh], &mut rng);
            let k = Tensor::randn(&[hk, n, dh], &mut rng);
            let v = Tensor::randn(&[hk, n, dh], &mut rng);
            let d = dense_attention_with(W, &q, &k, &v)
                .max_abs_diff(&dense_attention_with(S, &q, &k, &v));
            if d >= TOL {
                return Err(format!("dense_attention arms diverge by {d}"));
            }
            let d = antidiag_scores_with(W, &q, &k, block, stride)
                .max_abs_diff(&antidiag_scores_with(S, &q, &k, block, stride));
            if d >= TOL {
                return Err(format!("antidiag_scores arms diverge by {d}"));
            }
            let d = oam_scores_with(W, &q, &k, &v, block, stride, 0.2)
                .max_abs_diff(&oam_scores_with(S, &q, &k, &v, block, stride, 0.2));
            if d >= TOL {
                return Err(format!("oam_scores arms diverge by {d}"));
            }
            // one deterministic selection reused by both arms: cross-arm
            // top-k tie-breaks must never leak into this comparison
            let sel = select_streaming(h, nblk, 1, 2);
            let d = block_sparse_attention_with(W, &q, &k, &v, &sel, block)
                .max_abs_diff(&block_sparse_attention_with(S, &q, &k, &v, &sel, block));
            if d >= TOL {
                return Err(format!("block_sparse_attention arms diverge by {d}"));
            }
            Ok(())
        },
    );
}

#[test]
fn decode_kernels_agree_across_arms_on_prefix_views() {
    forall(
        67,
        14,
        |r: &mut Rng| {
            (
                r.below(1 << 31),
                1 + r.below(300) as usize, // n_tokens incl. partial tails
                1 + r.below(31) as usize,  // block size: straddles pages
                r.below(2) == 0,           // gqa
            )
        },
        |&(seed, n_tokens, block, gqa)| {
            let mut rng = Rng::new(seed);
            let (h, dh) = (4usize, 16usize);
            let hk = if gqa { 2 } else { 4 };
            let q = Tensor::randn(&[h, dh], &mut rng);
            let k = Tensor::randn(&[hk, 320, dh], &mut rng);
            let v = Tensor::randn(&[hk, 320, dh], &mut rng);
            let kv = TensorKv { k: &k, v: &v, n_tokens, block };
            // a KvPrefix clamped mid-block straddles the page boundary
            let pre = KvPrefix::new(&kv, n_tokens.saturating_sub(block / 2).max(1));
            let ws = decode_block_scores_with(W, &q, &pre, 4, 0.2);
            let ss = decode_block_scores_with(S, &q, &pre, 4, 0.2);
            let d = ws.max_abs_diff(&ss);
            if d >= TOL {
                return Err(format!("decode_block_scores arms diverge by {d}"));
            }
            // one selection (from the scalar scores) reused by both arms
            let sel = select_decode(&ss, 4, 1, 1);
            let d = maxdiff(
                &sparse_decode_attention_with(W, &q, &pre, &sel),
                &sparse_decode_attention_with(S, &q, &pre, &sel),
            );
            if d >= TOL {
                return Err(format!("sparse_decode_attention arms diverge by {d}"));
            }
            let d = maxdiff(
                &dense_decode_attention_with(W, &q, &kv),
                &dense_decode_attention_with(S, &q, &kv),
            );
            if d >= TOL {
                return Err(format!("dense_decode_attention arms diverge by {d}"));
            }
            Ok(())
        },
    );
}

#[test]
fn verify_kernel_agrees_across_arms_on_gamma_staircases() {
    forall(
        71,
        12,
        |r: &mut Rng| {
            (
                r.below(1 << 31),
                1 + r.below(6) as usize,   // γ rows
                1 + r.below(200) as usize, // base tokens
                r.below(2) == 0,           // gqa
            )
        },
        |&(seed, g_rows, base, gqa)| {
            let mut rng = Rng::new(seed);
            let (h, dh, block) = (4usize, 16usize, 32usize);
            let hk = if gqa { 2 } else { 4 };
            let q = Tensor::randn(&[g_rows, h, dh], &mut rng);
            let k = Tensor::randn(&[hk, 256, dh], &mut rng);
            let v = Tensor::randn(&[hk, 256, dh], &mut rng);
            let kv = TensorKv { k: &k, v: &v, n_tokens: base + g_rows - 1, block };
            let sel = Selection::verify_full(h, g_rows, kv.n_blocks());
            let d = maxdiff(
                &sparse_verify_attention_with(W, &q, &kv, &sel, base),
                &sparse_verify_attention_with(S, &q, &kv, &sel, base),
            );
            if d >= TOL {
                return Err(format!("sparse_verify_attention arms diverge by {d}"));
            }
            Ok(())
        },
    );
}

#[test]
fn malformed_decode_selections_are_rejected_before_the_simd_walk() {
    // fuzz the invariants the vectorized cursor walk depends on: each
    // mutation breaks exactly one, and validate_decode must catch it
    // (the kernels debug_assert this validation at their entry)
    forall(
        73,
        40,
        |r: &mut Rng| (r.below(1 << 31), r.below(5) as usize),
        |&(seed, mutation)| {
            let mut rng = Rng::new(seed);
            let (h, dh, block, nblk) = (2usize, 8usize, 16usize, 6usize);
            let k = Tensor::randn(&[h, nblk * block, dh], &mut rng);
            let v = Tensor::randn(&[h, nblk * block, dh], &mut rng);
            let q = Tensor::randn(&[h, dh], &mut rng);
            let kv = TensorKv { k: &k, v: &v, n_tokens: nblk * block - 3, block };
            // start from a valid ascending row, then break one invariant
            let mut rows: Vec<Vec<u32>> = vec![vec![0, 2, 4]; h];
            let expect_err = match mutation {
                0 => {
                    rows[1] = vec![0, 2, 2]; // duplicate id: double-counts
                    true
                }
                1 => {
                    rows[1] = vec![2, 0, 4]; // misaligned: walk skips id 0
                    true
                }
                2 => {
                    rows[1] = vec![0, 2, nblk as u32]; // beyond context
                    true
                }
                3 => {
                    rows[1] = vec![]; // empty row
                    true
                }
                _ => false, // control arm: stays valid
            };
            let mut b = SelectionBuilder::new(h, 1);
            for row in &rows {
                b.push_row(row, row.len() as u32);
            }
            let sel = b.finish();
            let verdict = sel.validate_decode(kv.n_blocks());
            if expect_err != verdict.is_err() {
                return Err(format!("mutation {mutation}: validate_decode said {verdict:?}"));
            }
            if verdict.is_ok() {
                // surviving selections must flow through both arms alike
                let d = maxdiff(
                    &sparse_decode_attention_with(W, &q, &kv, &sel),
                    &sparse_decode_attention_with(S, &q, &kv, &sel),
                );
                if d >= TOL {
                    return Err(format!("arms diverge on valid selection by {d}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn malformed_verify_selections_are_rejected_before_the_simd_walk() {
    forall(
        79,
        40,
        |r: &mut Rng| (r.below(1 << 31), r.below(5) as usize),
        |&(seed, mutation)| {
            let mut rng = Rng::new(seed);
            let (g_rows, h, dh, block, nblk, base) = (2usize, 1usize, 8usize, 16usize, 6, 80usize);
            let k = Tensor::randn(&[h, nblk * block, dh], &mut rng);
            let v = Tensor::randn(&[h, nblk * block, dh], &mut rng);
            let q = Tensor::randn(&[g_rows, h, dh], &mut rng);
            let kv = TensorKv { k: &k, v: &v, n_tokens: base + g_rows - 1, block };
            let mut rows: Vec<Vec<u32>> = vec![vec![0, 3], vec![0, 3, 5]];
            let expect_err = match mutation {
                0 => {
                    rows[1] = vec![0, 3, 3];
                    true
                }
                1 => {
                    rows[1] = vec![3, 0, 5];
                    true
                }
                2 => {
                    rows[1] = vec![0, 3, nblk as u32];
                    true
                }
                3 => {
                    rows[0] = vec![];
                    true
                }
                _ => false,
            };
            let mut b = SelectionBuilder::new(h, g_rows);
            for row in &rows {
                b.push_row(row, row.len() as u32);
            }
            let sel = b.finish();
            let verdict = sel.validate_verify(kv.n_blocks());
            if expect_err != verdict.is_err() {
                return Err(format!("mutation {mutation}: validate_verify said {verdict:?}"));
            }
            if verdict.is_ok() {
                let d = maxdiff(
                    &sparse_verify_attention_with(W, &q, &kv, &sel, base),
                    &sparse_verify_attention_with(S, &q, &kv, &sel, base),
                );
                if d >= TOL {
                    return Err(format!("arms diverge on valid selection by {d}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
#[cfg_attr(not(debug_assertions), ignore)]
#[should_panic(expected = "decode selection")]
fn decode_kernel_asserts_on_malformed_selection_in_debug() {
    let mut rng = Rng::new(9);
    let (h, dh, block) = (1usize, 8usize, 16usize);
    let k = Tensor::randn(&[h, 64, dh], &mut rng);
    let v = Tensor::randn(&[h, 64, dh], &mut rng);
    let q = Tensor::randn(&[h, dh], &mut rng);
    let kv = TensorKv { k: &k, v: &v, n_tokens: 64, block };
    let mut b = SelectionBuilder::new(1, 1);
    b.push_row(&[2, 1], 2); // descending: the cursor walk would skip id 1
    let sel = b.finish();
    let _ = sparse_decode_attention_with(S, &q, &kv, &sel);
}

#[test]
fn dispatch_globals_round_trip() {
    // the only test in the suite that touches the process-global
    // override; everything else passes arms explicitly, so this cannot
    // race with concurrently running properties
    if let Ok(env) = std::env::var("STEM_SIMD") {
        if let Ok(Some(arm)) = simd::parse(&env) {
            assert_eq!(simd::active(), arm, "STEM_SIMD={env} must pin dispatch");
        }
    }
    simd::set_override(Some(SimdArm::Scalar));
    assert_eq!(simd::active(), SimdArm::Scalar);
    assert_eq!(simd::dispatch_label(), "scalar");
    simd::set_override(Some(SimdArm::Wide));
    assert_eq!(simd::active(), SimdArm::Wide);
    assert!(simd::dispatch_label().starts_with("wide-"));
    simd::set_override(None);
}
