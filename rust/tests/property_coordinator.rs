//! Property tests on coordinator + sparse-core invariants (DESIGN.md §7).
//! These need no artifacts — they drive the pure-logic substrates with
//! the hand-rolled `forall` harness (util::prop).

use std::time::{Duration, Instant};

use stem::coordinator::admission::{Admission, AdmissionConfig, Admit};
use stem::coordinator::batcher::{BatchKey, Batcher, BatcherConfig};
use stem::coordinator::kv_cache::{KvCache, KvConfig};
use stem::coordinator::{Method, PrefillRequest};
use stem::sparse::schedule::{
    block_budget_schedule, cost_decay, cost_dense, cost_uniform, k_avg_blocks,
    k_uniform_matched, TpdConfig,
};
use stem::sparse::{
    block_sparse_attention, block_sparse_attention_reference, select_stem, select_stem_reference,
    SelectionBuilder, Tensor,
};
use stem::util::json::Json;
use stem::util::prop::forall;
use stem::util::rng::Rng;

fn req(id: u64) -> PrefillRequest {
    PrefillRequest {
        id,
        checkpoint: "base".into(),
        method: Method::Dense,
        ids: vec![],
        diag: false,
        enqueued: Instant::now(),
        deadline: None,
    }
}

// --- KV pool -----------------------------------------------------------

#[test]
fn kv_pool_conserves_pages_under_random_workload() {
    forall(
        101,
        60,
        |r: &mut Rng| {
            // (total_pages, ops: (alloc? tokens) interleaved with frees)
            let total = 16 + r.below(64) as usize;
            let ops: Vec<(u64, usize)> =
                (0..40).map(|i| (i as u64, 1 + r.below(900) as usize)).collect();
            (total, ops)
        },
        |(total, ops)| {
            let mut kv = KvCache::new(KvConfig { total_pages: *total, page_tokens: 64 });
            let mut live: Vec<u64> = vec![];
            for (id, tokens) in ops {
                match kv.allocate(*id, *tokens) {
                    Ok(pages) => {
                        if pages.len() != tokens.div_ceil(64) {
                            return Err(format!("wrong page count for {tokens} tokens"));
                        }
                        live.push(*id);
                    }
                    Err(_) => {
                        // free everything live and retry once
                        for l in live.drain(..) {
                            let _ = kv.release(l);
                            let _ = kv.drop_seq(l);
                        }
                        if kv.used_pages() != 0 {
                            return Err("pages leaked after full drain".into());
                        }
                    }
                }
                let used: usize = kv.used_pages();
                if used + kv.free_pages() != *total {
                    return Err("page conservation violated".into());
                }
            }
            for l in live.drain(..) {
                let _ = kv.release(l);
                let _ = kv.drop_seq(l);
            }
            if kv.used_pages() != 0 {
                return Err("pages leaked at end".into());
            }
            Ok(())
        },
    );
}

#[test]
fn kv_pool_no_double_grant() {
    let mut kv = KvCache::new(KvConfig { total_pages: 32, page_tokens: 64 });
    let a = kv.allocate(1, 512).unwrap().to_vec();
    let b = kv.allocate(2, 512).unwrap().to_vec();
    for p in &a {
        assert!(!b.contains(p), "page {p} granted twice");
    }
    assert_eq!(kv.allocate(3, 64 * 64), Err(stem::coordinator::kv_cache::KvError::OutOfPages { need: 64, free: 16 }));
}

// --- batcher -----------------------------------------------------------

#[test]
fn batcher_never_mixes_keys_and_preserves_fifo() {
    forall(
        102,
        60,
        |r: &mut Rng| {
            let n = 1 + r.below(60) as usize;
            let picks: Vec<usize> = (0..n).map(|_| r.below(3) as usize).collect();
            picks
        },
        |picks| {
            let keys = [
                BatchKey { kind: "prefill_dense", bucket: 512, checkpoint: "base".into() },
                BatchKey { kind: "prefill_stem", bucket: 512, checkpoint: "base".into() },
                BatchKey { kind: "prefill_stem", bucket: 1024, checkpoint: "base".into() },
            ];
            let mut b = Batcher::new(BatcherConfig { max_batch: 4, max_wait: Duration::ZERO });
            let mut pushed = 0u64;
            for &p in picks {
                pushed += 1;
                b.push(keys[p].clone(), req(pushed));
            }
            let mut seen = 0usize;
            let mut last_id_per_key = std::collections::BTreeMap::new();
            let now = Instant::now() + Duration::from_secs(1);
            let mut batches = vec![];
            while let Some(batch) = b.pop_ready(now) {
                batches.push(batch);
            }
            batches.extend(b.drain_all(now));
            for batch in batches {
                if batch.requests.is_empty() {
                    return Err("empty batch emitted".into());
                }
                if batch.requests.len() > 4 {
                    return Err("batch exceeds max_batch".into());
                }
                for r in &batch.requests {
                    seen += 1;
                    let last = last_id_per_key.entry(batch.key.clone()).or_insert(0u64);
                    if r.id <= *last {
                        return Err(format!("FIFO violated in {:?}", batch.key));
                    }
                    *last = r.id;
                }
            }
            if seen != picks.len() {
                return Err(format!("conservation: pushed {} popped {seen}", picks.len()));
            }
            Ok(())
        },
    );
}

#[test]
fn batcher_timeout_flushes_partial_batches() {
    let mut b = Batcher::new(BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(1) });
    let key = BatchKey { kind: "prefill_dense", bucket: 512, checkpoint: "base".into() };
    b.push(key.clone(), req(1));
    assert!(b.pop_ready(Instant::now()).is_none(), "must wait for max_wait");
    let later = Instant::now() + Duration::from_millis(5);
    let batch = b.pop_ready(later).expect("timeout flush");
    assert_eq!(batch.requests.len(), 1);
}

// --- admission ---------------------------------------------------------

#[test]
fn admission_never_exceeds_limits() {
    forall(
        103,
        80,
        |r: &mut Rng| {
            // (tokens, op) — op even = admit, odd = release
            let ops: Vec<(usize, usize)> =
                (0..50).map(|_| (1 + r.below(2000) as usize, r.below(2) as usize)).collect();
            ops
        },
        |ops| {
            let cfg = AdmissionConfig { max_tokens: 8192, max_requests: 16, ..Default::default() };
            let adm = Admission::new(cfg);
            let mut live: Vec<usize> = vec![];
            for (tokens, op) in ops {
                if *op == 1 {
                    if let Some(t) = live.pop() {
                        adm.release(t);
                    }
                    continue;
                }
                match adm.try_admit(*tokens) {
                    Admit::Accepted => live.push(*tokens),
                    Admit::Rejected { .. } => {}
                }
                let (tok, reqs) = adm.outstanding();
                if tok > cfg.max_tokens || reqs > cfg.max_requests {
                    return Err(format!("limits exceeded: {tok} tokens / {reqs} reqs"));
                }
                if tok != live.iter().sum::<usize>() || reqs != live.len() {
                    return Err("accounting drift".into());
                }
            }
            Ok(())
        },
    );
}

/// Satellite: concurrent admission churn. Several threads hammer
/// accept / reject / release (rejections modelled as client timeouts
/// that give back an older admission) while a blocking admitter waits
/// on the Condvar. Every thread must finish — a wedged Condvar waiter
/// hangs the test — and the counters must balance back to zero.
#[test]
fn admission_concurrent_churn_balances_and_never_wedges() {
    use std::sync::Arc;

    forall(
        115,
        6,
        |r: &mut Rng| (r.below(1 << 31), 2 + r.below(3) as usize),
        |&(seed, n_threads)| {
            let adm = Arc::new(Admission::new(AdmissionConfig {
                max_tokens: 4096,
                max_requests: 8,
                max_work_ns: 1e9,
            }));
            let mut churners = vec![];
            for t in 0..n_threads {
                let adm = Arc::clone(&adm);
                churners.push(std::thread::spawn(move || {
                    let mut rng = Rng::new(seed ^ (t as u64 + 1));
                    let mut live: Vec<(usize, f64)> = vec![];
                    for _ in 0..400 {
                        let tokens = 1 + rng.below(1024) as usize;
                        let est = rng.f64() * 1e7;
                        match adm.try_admit_work(tokens, est) {
                            Admit::Accepted => live.push((tokens, est)),
                            // shed: model the client timing out an older
                            // admission of ours, freeing capacity
                            Admit::Rejected { .. } => {
                                if let Some((tk, e)) = live.pop() {
                                    adm.release_work(tk, e);
                                }
                            }
                        }
                        if rng.below(3) == 0 {
                            if let Some((tk, e)) = live.pop() {
                                adm.release_work(tk, e);
                            }
                        }
                    }
                    for (tk, e) in live.drain(..) {
                        adm.release_work(tk, e);
                    }
                }));
            }
            // a blocking admitter racing the churn: it must wake and
            // finish once capacity frees up, never wedge on the Condvar
            let blocker = {
                let adm = Arc::clone(&adm);
                std::thread::spawn(move || {
                    adm.admit_blocking(64);
                    adm.release(64);
                })
            };
            for h in churners {
                h.join().map_err(|_| "churn thread panicked".to_string())?;
            }
            blocker.join().map_err(|_| "blocking admitter panicked".to_string())?;
            let (tok, reqs) = adm.outstanding();
            if (tok, reqs) != (0, 0) {
                return Err(format!("counters did not balance: {tok} tokens / {reqs} reqs"));
            }
            // release clamps at zero, so fp drift may only leave a
            // negligible positive residue
            if adm.outstanding_work_ns() > 1.0 {
                return Err(format!("work_ns residue {}", adm.outstanding_work_ns()));
            }
            Ok(())
        },
    );
}

// --- schedule algebra ---------------------------------------------------

#[test]
fn budget_matched_uniform_equals_decay_cost() {
    forall(
        104,
        120,
        |r: &mut Rng| (512 + r.below(1 << 15) as usize, 0.3 + r.f64() * 0.69, 4.0 + r.f64() * 60.0),
        |&(n, mu, ks)| {
            // §3.3's k_uni = k_start(1+μ)/2 drops the -k²/2 term, so it is
            // exact only for k ≪ N — the paper's operating regime
            // (budgets ≤ ~30%). Outside it the rule legitimately drifts.
            if ks * 64.0 >= 0.3 * n as f64 {
                return Ok(());
            }
            let cu = cost_uniform(n, k_uniform_matched(ks, mu) * 64.0);
            let cd = cost_decay(n, ks * 64.0, mu);
            let rel = (cu - cd).abs() / cd.max(1.0);
            if rel < 0.05 {
                Ok(())
            } else {
                Err(format!("matched-cost rule off by {:.1}%", rel * 100.0))
            }
        },
    );
}

#[test]
fn decay_savings_term_matches_paper_eq4() {
    // C_uni - C_decay == 0.5·k_start·(1-μ)·(N-k_start) exactly (Eq. 4)
    forall(
        105,
        120,
        |r: &mut Rng| (1024 + r.below(1 << 16) as usize, 0.3 + r.f64() * 0.7, 64.0 + r.f64() * 4096.0),
        |&(n, mu, ks)| {
            let savings = cost_uniform(n, ks) - cost_decay(n, ks, mu);
            let want = 0.5 * ks * (1.0 - mu) * (n as f64 - ks);
            if (savings - want).abs() < 1e-6 * want.abs().max(1.0) {
                Ok(())
            } else {
                Err(format!("savings {savings} != Eq.4 {want}"))
            }
        },
    );
}

#[test]
fn k_avg_between_k_end_and_k_start() {
    forall(
        106,
        100,
        |r: &mut Rng| (8 + r.below(120) as usize, 0.3 + r.f64() * 0.7, 3.0 + r.f64() * 20.0),
        |&(nblk, mu, ks)| {
            let cfg = TpdConfig { k_start: ks, mu, ..Default::default() };
            let kavg = k_avg_blocks(nblk, &cfg);
            // causal clamping can push below μ·k_start on tiny grids; the
            // hard invariants are positivity and the k_start ceiling.
            if kavg <= 0.0 {
                return Err("k_avg <= 0".into());
            }
            if kavg > ks.max(cfg.min_total as f64) + 1.0 {
                return Err(format!("k_avg {kavg} above k_start {ks}"));
            }
            Ok(())
        },
    );
}

#[test]
fn schedule_cost_never_exceeds_dense() {
    forall(
        107,
        100,
        |r: &mut Rng| (16 + r.below(100) as usize, 0.3 + r.f64() * 0.7, 2.0 + r.f64() * 40.0),
        |&(nblk, mu, ks)| {
            let cfg = TpdConfig { k_start: ks, mu, ..Default::default() };
            let total: usize = block_budget_schedule(nblk, &cfg).iter().sum();
            let dense = nblk * (nblk + 1) / 2;
            if total <= dense {
                Ok(())
            } else {
                Err(format!("selected {total} block-pairs > dense {dense}"))
            }
        },
    );
    let _ = cost_dense(8);
}

// --- selection invariants under random inputs ---------------------------

#[test]
fn stem_selection_always_valid() {
    forall(
        108,
        20,
        |r: &mut Rng| (r.below(1 << 31), 2 + r.below(6) as usize, 0.3 + r.f64() * 0.7),
        |&(seed, nblk, mu)| {
            let mut rng = Rng::new(seed);
            let block = 32;
            let n = nblk * block;
            let q = Tensor::randn(&[2, n, 16], &mut rng);
            let k = Tensor::randn(&[1, n, 16], &mut rng);
            let v = Tensor::randn(&[1, n, 16], &mut rng);
            let cfg = TpdConfig { k_start: 3.0, mu, ..Default::default() };
            let sel = select_stem(&q, &k, &v, block, 8, &cfg, 0.2);
            sel.validate()?;
            let bud = sel.budget_fraction();
            if !(0.0..=1.0 + 1e-9).contains(&bud) {
                return Err(format!("budget {bud} out of range"));
            }
            Ok(())
        },
    );
}

// --- parallel fused kernel vs retained scalar reference ------------------

#[test]
fn fused_parallel_kernel_matches_scalar_reference() {
    forall(
        110,
        12,
        |r: &mut Rng| {
            (
                r.below(1 << 31),
                2 + r.below(5) as usize,      // nblk
                2 + 2 * r.below(2) as usize,  // h in {2, 4}
                2.0 + r.f64() * 6.0,          // k_start
            )
        },
        |&(seed, nblk, h, ks)| {
            if nblk == 0 || h < 2 || ks <= 0.0 {
                return Ok(()); // shrink candidates outside the domain
            }
            let mut rng = Rng::new(seed);
            let block = 32;
            let n = nblk * block;
            let hk = h / 2;
            let q = Tensor::randn(&[h, n, 16], &mut rng);
            let k = Tensor::randn(&[hk, n, 16], &mut rng);
            let v = Tensor::randn(&[hk, n, 16], &mut rng);
            let cfg = TpdConfig { k_start: ks, mu: 0.7, ..Default::default() };
            let fast = select_stem(&q, &k, &v, block, 8, &cfg, 0.2);
            let slow = select_stem_reference(&q, &k, &v, block, 8, &cfg, 0.2);
            if fast.indices != slow.indices
                || fast.counts != slow.counts
                || fast.row_offsets != slow.row_offsets
            {
                return Err("partial top-k selection diverges from full sort".into());
            }
            fast.validate()?;
            let fused = block_sparse_attention(&q, &k, &v, &fast, block);
            let reference = block_sparse_attention_reference(&q, &k, &v, &fast, block);
            let d = fused.max_abs_diff(&reference);
            if d >= 1e-5 {
                return Err(format!("fused kernel deviates from reference by {d}"));
            }
            Ok(())
        },
    );
}

#[test]
fn csr_selection_validate_rejects_adversarial_rows() {
    forall(
        111,
        120,
        |r: &mut Rng| {
            (
                r.below(1 << 31),
                2 + r.below(8) as usize, // nblk
                r.below(3) as usize,     // corruption kind
            )
        },
        |&(seed, nblk, kind)| {
            if nblk == 0 {
                return Ok(()); // shrink candidates outside the domain
            }
            let mut rng = Rng::new(seed);
            // build a random *valid* selection: each row keeps a random
            // nonempty subset of its causal width
            let mut rows: Vec<Vec<u32>> = vec![];
            for i in 0..nblk {
                let mut row: Vec<u32> = (0..=i as u32).collect();
                // random causal permutation prefix
                for j in (1..row.len()).rev() {
                    let swap = rng.below(j as u64 + 1) as usize;
                    row.swap(j, swap);
                }
                let keep = 1 + rng.below(i as u64 + 1) as usize;
                row.truncate(keep);
                rows.push(row);
            }
            let mut b = SelectionBuilder::new(1, nblk);
            for row in &rows {
                b.push_row(row, row.len() as u32);
            }
            let sel = b.finish();
            sel.validate().map_err(|e| format!("valid CSR rejected: {e}"))?;

            // corrupt one row and require validate() to reject it
            let victim = rng.below(nblk as u64) as usize;
            let mut bad_rows = rows.clone();
            match kind {
                0 => {
                    // duplicate entry
                    let first = bad_rows[victim][0];
                    bad_rows[victim].push(first);
                }
                1 => {
                    // non-causal entry
                    bad_rows[victim].push(victim as u32 + 1);
                }
                _ => {
                    // zero count handled below
                }
            }
            let mut bb = SelectionBuilder::new(1, nblk);
            for (i, row) in bad_rows.iter().enumerate() {
                let count = if kind == 2 && i == victim { 0 } else { row.len() as u32 };
                bb.push_row(row, count);
            }
            let bad = bb.finish();
            if bad.validate().is_ok() {
                return Err(format!("corruption kind {kind} at row {victim} not rejected"));
            }
            Ok(())
        },
    );
}

// --- KV pool decode paths (fork / append / cow / evict) ------------------

#[test]
fn kv_pool_invariants_under_random_fork_append_drop() {
    forall(
        112,
        50,
        |r: &mut Rng| {
            // (op, magnitude): 0 alloc, 1 append, 2 fork, 3 release, 4 drop
            let ops: Vec<(usize, usize)> =
                (0..50).map(|_| (r.below(5) as usize, 1 + r.below(200) as usize)).collect();
            ops
        },
        |ops| {
            let mut kv = KvCache::new(KvConfig { total_pages: 24, page_tokens: 64 });
            let mut next_id = 0u64;
            let mut live: Vec<u64> = vec![];
            for &(op, mag) in ops {
                match op {
                    0 => {
                        next_id += 1;
                        if kv.allocate(next_id, mag).is_ok() {
                            live.push(next_id);
                        }
                    }
                    1 => {
                        if let Some(&id) = live.last() {
                            let before = kv.seq_tokens(id);
                            match kv.append_tokens(id, mag) {
                                Ok(a) => {
                                    if let Some((old, new)) = a.cow {
                                        if old == new {
                                            return Err("cow to the same page".into());
                                        }
                                    }
                                    if kv.seq_tokens(id) != before.map(|b| b + mag) {
                                        return Err("append lost tokens".into());
                                    }
                                }
                                Err(_) => {
                                    if kv.seq_tokens(id) != before {
                                        return Err(
                                            "failed append must not change tokens".into()
                                        );
                                    }
                                }
                            }
                        }
                    }
                    2 => {
                        if let Some(&src) = live.first() {
                            next_id += 1;
                            if kv.fork(src, next_id).is_ok() {
                                live.push(next_id);
                            }
                        }
                    }
                    3 => {
                        if !live.is_empty() {
                            let _ = kv.release(live[mag % live.len()]);
                        }
                    }
                    _ => {
                        if !live.is_empty() {
                            let id = live.remove(mag % live.len());
                            let _ = kv.drop_seq(id);
                        }
                    }
                }
                // drop evicted sequences from our live set
                live.retain(|id| kv.page_table(*id).is_some());
                kv.check_invariants()?;
            }
            for id in live.drain(..) {
                let _ = kv.release(id);
                let _ = kv.drop_seq(id);
            }
            if kv.used_pages() != 0 {
                return Err("pages leaked after full drain".into());
            }
            Ok(())
        },
    );
}

// --- decode kernels vs dense oracle --------------------------------------

#[test]
fn sparse_decode_full_budget_matches_dense_oracle() {
    use stem::sparse::{
        dense_decode_attention_reference, sparse_decode_attention, KvBlocks, Selection, TensorKv,
    };
    forall(
        113,
        16,
        |r: &mut Rng| {
            (
                r.below(1 << 31),
                1 + r.below(300) as usize,    // n_tokens (partial tail blocks)
                2 + 2 * r.below(2) as usize,  // h in {2, 4}
                1 + r.below(31) as usize,     // block
                r.below(2) == 0,              // gqa
            )
        },
        |&(seed, n_tokens, h, block, gqa)| {
            if n_tokens == 0 || h < 2 || block == 0 {
                return Ok(()); // shrink candidates outside the domain
            }
            let mut rng = Rng::new(seed);
            let hk = if gqa { h / 2 } else { h };
            let dh = 16;
            let q = Tensor::randn(&[h, dh], &mut rng);
            let k = Tensor::randn(&[hk, 320, dh], &mut rng);
            let v = Tensor::randn(&[hk, 320, dh], &mut rng);
            let kv = TensorKv { k: &k, v: &v, n_tokens, block };
            let sel = Selection::decode_full(h, kv.n_blocks());
            sel.validate_decode(kv.n_blocks())?;
            let sparse = sparse_decode_attention(&q, &kv, &sel);
            let dense = dense_decode_attention_reference(&q, &kv);
            let d = sparse
                .iter()
                .zip(&dense)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            if d >= 1e-5 {
                return Err(format!("decode kernel deviates from dense oracle by {d}"));
            }
            Ok(())
        },
    );
}

#[test]
fn decode_selection_always_valid_under_random_budgets() {
    use stem::sparse::{decode_block_scores, select_decode, KvBlocks, TensorKv};
    forall(
        114,
        30,
        |r: &mut Rng| {
            (
                r.below(1 << 31),
                32 + r.below(480) as usize, // n_tokens
                1 + r.below(12) as usize,   // budget
                r.below(3) as usize,        // sink
                1 + r.below(3) as usize,    // recent
            )
        },
        |&(seed, n_tokens, budget, sink, recent)| {
            if n_tokens == 0 || budget == 0 || recent == 0 {
                return Ok(()); // shrink candidates outside the domain
            }
            let mut rng = Rng::new(seed);
            let (h, hk, dh, block) = (4usize, 2usize, 8usize, 32usize);
            let q = Tensor::randn(&[h, dh], &mut rng);
            let k = Tensor::randn(&[hk, 512, dh], &mut rng);
            let v = Tensor::randn(&[hk, 512, dh], &mut rng);
            let kv = TensorKv { k: &k, v: &v, n_tokens, block };
            let scores = decode_block_scores(&q, &kv, 8, 0.2);
            let sel = select_decode(&scores, budget, sink, recent);
            sel.validate_decode(kv.n_blocks())?;
            let nblk = kv.n_blocks();
            for hh in 0..h {
                let row = sel.selected(hh, 0);
                if row.len() != budget.min(nblk) {
                    return Err(format!("head {hh}: {} != budget {}", row.len(), budget.min(nblk)));
                }
                // forced sets are only guaranteed when the budget can hold
                // them (DecodePolicy keeps budget >= sink + recent)
                if budget < nblk && budget >= sink + recent {
                    for s in 0..sink.min(nblk) as u32 {
                        if !row.contains(&s) {
                            return Err(format!("head {hh}: sink {s} dropped"));
                        }
                    }
                    let last = (nblk - 1) as u32;
                    if !row.contains(&last) {
                        return Err(format!("head {hh}: newest block dropped"));
                    }
                }
            }
            Ok(())
        },
    );
}

// --- decode sessions against the shared pool -----------------------------

#[test]
fn concurrent_decode_sessions_share_the_pool_without_corruption() {
    use std::sync::Arc;
    use stem::decode::{DecodePolicy, DecodeSession, SharedKv, TinyLm};

    let kv = SharedKv::new(KvConfig { total_pages: 256, page_tokens: 16 }, 2, 8);
    let model = Arc::new(TinyLm::new(3, 4, 2, 8, 96));
    // reference stream, generated alone
    let solo = {
        let kv2 = SharedKv::new(KvConfig { total_pages: 256, page_tokens: 16 }, 2, 8);
        let mut s =
            DecodeSession::new(kv2, Arc::clone(&model), DecodePolicy::default(), 1).unwrap();
        s.prefill(&[1, 17, 18, 19]).unwrap();
        s.generate(8, None, |_| true).unwrap().tokens
    };
    // three sessions interleaved step-by-step on one shared store
    let mut sessions: Vec<DecodeSession> = (1..=3)
        .map(|i| {
            let mut s = DecodeSession::new(
                Arc::clone(&kv),
                Arc::clone(&model),
                DecodePolicy::default(),
                i,
            )
            .unwrap();
            s.prefill(&[1, 17, 18, 19]).unwrap();
            s
        })
        .collect();
    let mut streams = vec![vec![]; 3];
    for _ in 0..8 {
        for (i, s) in sessions.iter_mut().enumerate() {
            streams[i].push(s.step_once().unwrap().token);
        }
    }
    kv.pool().unwrap().check_invariants().unwrap();
    for stream in &streams {
        assert_eq!(stream, &solo, "interleaving must not change any stream");
    }
    drop(sessions);
    assert_eq!(kv.pool().unwrap().used_pages(), 0);
    assert_eq!(kv.pages_resident(), 0, "shared slabs must GC with their pages");
}

/// Satellite: randomized fork-tree property test. Builds a root → child
/// → grandchild chain (depth 3) over the shared store, then interleaves
/// random forks, appends and drops across the tree. After every op,
/// every live session's `SeqKvView` must expose exactly the K/V of its
/// own token history — a sibling's appended tokens must never leak
/// through a shared page — and the pool invariants must hold.
#[test]
fn fork_tree_cow_isolation_under_random_ops() {
    use std::sync::Arc;
    use stem::decode::{DecodePolicy, DecodeSession, SharedKv, TinyLm};
    use stem::sparse::KvBlocks;

    const PT: usize = 8; // page_tokens
    const HK: usize = 2;
    const DH: usize = 8;

    forall(
        117,
        10,
        |r: &mut Rng| {
            // (op selector, session selector, token) triples
            let ops: Vec<(usize, usize, usize)> = (0..24)
                .map(|_| (r.below(8) as usize, r.below(32) as usize, r.below(40) as usize))
                .collect();
            ops
        },
        |ops| {
            let kv = SharedKv::new(KvConfig { total_pages: 256, page_tokens: PT }, HK, DH);
            let model = Arc::new(TinyLm::new(5, 4, HK, DH, 96));
            let mut next_seq = 1u64;
            let mut seq = || {
                next_seq += 1;
                next_seq
            };
            // live sessions with their expected token histories
            let mut live: Vec<(DecodeSession, Vec<i32>)> = vec![];
            let policy = DecodePolicy::default();
            let mut root =
                DecodeSession::new(Arc::clone(&kv), Arc::clone(&model), policy, 1)
                    .map_err(|e| e.to_string())?;
            let base: Vec<i32> = (0..12).map(|i| 16 + (i % 40)).collect();
            root.prefill(&base).map_err(|e| e.to_string())?;
            // guarantee depth >= 3: root -> child -> grandchild, each
            // diverged by one appended token
            let mut child = root.fork(seq()).map_err(|e| e.to_string())?;
            child.prefill(&[17]).map_err(|e| e.to_string())?;
            let mut grandchild = child.fork(seq()).map_err(|e| e.to_string())?;
            grandchild.prefill(&[18]).map_err(|e| e.to_string())?;
            let mut hist = base.clone();
            live.push((root, hist.clone()));
            hist.push(17);
            live.push((child, hist.clone()));
            hist.push(18);
            live.push((grandchild, hist));

            let verify = |live: &[(DecodeSession, Vec<i32>)]| -> Result<(), String> {
                for (s, hist) in live {
                    if s.n_ctx() != hist.len() {
                        return Err(format!(
                            "seq {}: n_ctx {} != history {}",
                            s.seq_id(),
                            s.n_ctx(),
                            hist.len()
                        ));
                    }
                    s.with_kv_view(|view| -> Result<(), String> {
                        for (pos, &tok) in hist.iter().enumerate() {
                            let (_, k, v) = model.project(tok, pos, false);
                            let (b, slot) = (pos / PT, pos % PT);
                            for hkv in 0..HK {
                                let want_k = &k[hkv * DH..(hkv + 1) * DH];
                                let got_k = &view.k_block(hkv, b)[slot * DH..(slot + 1) * DH];
                                if got_k != want_k {
                                    return Err(format!(
                                        "seq {}: K leak at pos {pos} head {hkv}",
                                        s.seq_id()
                                    ));
                                }
                                let want_v = &v[hkv * DH..(hkv + 1) * DH];
                                let got_v = &view.v_block(hkv, b)[slot * DH..(slot + 1) * DH];
                                if got_v != want_v {
                                    return Err(format!(
                                        "seq {}: V leak at pos {pos} head {hkv}",
                                        s.seq_id()
                                    ));
                                }
                            }
                        }
                        Ok(())
                    })
                    .map_err(|e| e.to_string())??;
                }
                kv.pool().map_err(|e| e.to_string())?.check_invariants()?;
                Ok(())
            };

            verify(&live)?;
            for &(op, who, tok) in ops {
                let idx = who % live.len();
                match op {
                    // fork the chosen session (tree grows arbitrarily deep)
                    0..=2 => {
                        let fork =
                            live[idx].0.fork(seq()).map_err(|e| e.to_string())?;
                        let hist = live[idx].1.clone();
                        live.push((fork, hist));
                    }
                    // append a token: diverges from every sharer via CoW
                    3..=6 => {
                        let t = 16 + tok as i32;
                        live[idx].0.prefill(&[t]).map_err(|e| e.to_string())?;
                        live[idx].1.push(t);
                    }
                    // drop a session (never the last one)
                    _ => {
                        if live.len() > 1 {
                            live.remove(idx);
                        }
                    }
                }
                verify(&live)?;
            }
            drop(live);
            let used = kv.pool().map_err(|e| e.to_string())?.used_pages();
            if used != 0 {
                return Err(format!("{used} pages leaked after dropping the tree"));
            }
            if kv.pages_resident() != 0 {
                return Err("slabs leaked after dropping the tree".into());
            }
            Ok(())
        },
    );
}

// --- json substrate ------------------------------------------------------

#[test]
fn json_roundtrips_numbers_and_nesting() {
    forall(
        109,
        200,
        |r: &mut Rng| {
            let depth = r.below(4) as usize;
            let x = (r.f64() - 0.5) * 1e6;
            (depth, x)
        },
        |&(depth, x)| {
            let mut s = format!("{x}");
            for _ in 0..depth {
                s = format!("[{s}, {{\"k\": {s}}}]");
            }
            let j = Json::parse(&s).map_err(|e| format!("parse: {e}"))?;
            let mut cur = &j;
            for _ in 0..depth {
                cur = &cur.as_arr().ok_or("not arr")?[0];
            }
            let got = cur.as_f64().ok_or("not num")?;
            if (got - x).abs() > 1e-9 * x.abs().max(1.0) {
                return Err(format!("{got} != {x}"));
            }
            Ok(())
        },
    );
}
