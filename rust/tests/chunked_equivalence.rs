//! Chunked-vs-monolithic ingest equivalence suite (ISSUE 9).
//!
//! The hard contract behind chunked prefill: splitting a prompt ingest
//! into chunks — any chunk size, any boundary — must be *invisible* in
//! every output. K/V depend only on `(token, position)`, so:
//!
//! * at the session level, a chunked [`DecodeSession::extend_prompt`]
//!   sequence must leave cached K/V within 1e-5 of a one-shot
//!   [`DecodeSession::prefill`] (they are in fact bit-identical) and the
//!   subsequent greedy stream must match byte for byte — for random
//!   prompts and chunk sizes including one page, sub-page ragged sizes
//!   and chunks larger than the whole prompt;
//! * radix partial hits compose with chunking: forking the covered
//!   pages and ingesting the divergent suffix in chunks equals a fresh
//!   full prefill of the combined prompt;
//! * at the coordinator level, a chunked coordinator
//!   (`chunk_tokens > 0`) and a monolithic one (`chunk_tokens = 0`)
//!   emit byte-identical token streams for the same requests, fan-out
//!   and prefix-reuse patterns included;
//! * a deadline that expires mid-ingest sheds *typed*
//!   ([`ServeError::DeadlineExceeded`] or a deadline-finish partial) at
//!   a chunk boundary, and holders/pages/admission fully unwind.
//!
//! Artifact-free; runs under `cargo test` like the other tier-1 suites.

use std::sync::Arc;
use std::time::{Duration, Instant};

use stem::coordinator::kv_cache::KvConfig;
use stem::coordinator::{Coordinator, CoordinatorConfig, Finish, ServeError};
use stem::decode::{DecodeBackend, DecodePolicy, DecodeSession, SharedKv, TinyLm};
use stem::model::vocab;
use stem::runtime::{PrefillBackend, SyntheticEngine};
use stem::sparse::KvBlocks;
use stem::util::prop::forall;
use stem::util::rng::Rng;

const H: usize = 4;
const HK: usize = 2;
const DH: usize = 16;
/// Session-level page size (small, so prompts span many pages).
const PAGE: usize = 16;

/// Anything not terminal by now is a hang, not slowness.
const TERMINAL: Duration = Duration::from_secs(60);

fn model() -> Arc<dyn DecodeBackend> {
    Arc::new(TinyLm::new(0xC0DE, H, HK, DH, vocab::VOCAB_SIZE))
}

fn pool() -> Arc<SharedKv> {
    SharedKv::new(KvConfig { total_pages: 256, page_tokens: PAGE }, HK, DH)
}

fn prompt_from(seed: u64, len: usize) -> Vec<i32> {
    let mut r = Rng::new(seed.wrapping_mul(0x9E37_79B9).wrapping_add(7));
    let mut p = vec![vocab::BOS];
    p.extend((1..len.max(1)).map(|_| vocab::WORD0 + r.below(64) as i32));
    p
}

/// Ingest `prompt[from..]` in `chunk`-sized pieces (tail ragged).
fn ingest_chunked(
    s: &mut DecodeSession,
    prompt: &[i32],
    from: usize,
    chunk: usize,
) -> Result<(), String> {
    for piece in prompt[from..].chunks(chunk.max(1)) {
        s.extend_prompt(piece).map_err(|e| format!("chunked ingest: {e}"))?;
    }
    Ok(())
}

/// Every cached K/V row of a session, flattened in (kv-head, block)
/// order, plus the block count — the ingest-state fingerprint.
fn kv_rows(s: &DecodeSession) -> (usize, Vec<f32>) {
    s.with_kv_view(|v| {
        let mut rows = Vec::new();
        for h in 0..HK {
            for b in 0..v.n_blocks() {
                rows.extend_from_slice(v.k_block(h, b));
                rows.extend_from_slice(v.v_block(h, b));
            }
        }
        (v.n_blocks(), rows)
    })
    .expect("kv view")
}

/// Max absolute deviation between two ingest fingerprints; errors on any
/// shape mismatch.
fn kv_deviation(a: &(usize, Vec<f32>), b: &(usize, Vec<f32>)) -> Result<f32, String> {
    if a.0 != b.0 {
        return Err(format!("block counts differ: {} vs {}", a.0, b.0));
    }
    if a.1.len() != b.1.len() {
        return Err(format!("row counts differ: {} vs {}", a.1.len(), b.1.len()));
    }
    Ok(a.1.iter().zip(&b.1).map(|(x, y)| (x - y).abs()).fold(0.0f32, f32::max))
}

#[test]
fn prop_chunked_ingest_matches_one_shot_prefill() {
    forall(
        0xC4A9,
        24,
        |r: &mut Rng| {
            (
                r.below(180) as usize + 1, // prompt length
                r.below(4) as usize,       // chunk-size selector
                r.below(16) as usize + 2,  // max_new
            )
        },
        |&(plen, csel, max_new)| {
            let prompt = prompt_from(plen as u64, plen);
            // the shapes the ISSUE calls out: exactly one page, ragged
            // sub-page sizes, and a chunk larger than the whole prompt
            let chunk = match csel % 4 {
                0 => PAGE,
                1 => 7,
                2 => prompt.len() + 5,
                _ => 3,
            };
            let mut mono = DecodeSession::new(pool(), model(), DecodePolicy::default(), 1)
                .map_err(|e| format!("mono session: {e}"))?;
            let mut chunked = DecodeSession::new(pool(), model(), DecodePolicy::default(), 1)
                .map_err(|e| format!("chunked session: {e}"))?;
            mono.prefill(&prompt).map_err(|e| format!("one-shot prefill: {e}"))?;
            ingest_chunked(&mut chunked, &prompt, 0, chunk)?;
            if mono.n_ctx() != chunked.n_ctx() || mono.last_token() != chunked.last_token() {
                return Err(format!(
                    "ingest state diverged (chunk={chunk}): ctx {}/{} last {}/{}",
                    mono.n_ctx(),
                    chunked.n_ctx(),
                    mono.last_token(),
                    chunked.last_token()
                ));
            }
            let dev = kv_deviation(&kv_rows(&mono), &kv_rows(&chunked))?;
            if dev >= 1e-5 {
                return Err(format!("cached K/V deviates by {dev} (chunk={chunk})"));
            }
            let a = mono.generate(max_new, None, |_| true).map_err(|e| format!("gen: {e}"))?;
            let b = chunked.generate(max_new, None, |_| true).map_err(|e| format!("gen: {e}"))?;
            if a.tokens != b.tokens {
                return Err(format!(
                    "streams diverged (chunk={chunk}):\n  mono:    {:?}\n  chunked: {:?}",
                    a.tokens, b.tokens
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn partial_prefix_fork_with_chunked_suffix_matches_full_prefill() {
    // radix partial hit where the suffix itself is chunked: fork the
    // covered pages off a parked holder, ingest the divergent tail in
    // ragged chunks, and demand equality with a fresh one-shot prefill
    let base = prompt_from(0xA11, 40); // 2.5 pages at PAGE=16
    let covered = 2 * PAGE; // whole covered pages only
    let kv = pool();
    let mut holder = DecodeSession::new(Arc::clone(&kv), model(), DecodePolicy::default(), 1)
        .expect("holder session");
    holder.prefill(&base).expect("holder prefill");

    let mut prompt = base[..covered].to_vec();
    prompt.extend(prompt_from(0xB22, 30).into_iter().skip(1)); // divergent suffix
    for chunk in [1usize, 5, PAGE, prompt.len()] {
        let mut forked = holder
            .fork_prefix(100 + chunk as u64, covered, prompt[covered - 1])
            .expect("fork covered pages");
        ingest_chunked(&mut forked, &prompt, covered, chunk).expect("suffix ingest");

        let mut mono = DecodeSession::new(pool(), model(), DecodePolicy::default(), 1)
            .expect("mono session");
        mono.prefill(&prompt).expect("mono prefill");

        let dev = kv_deviation(&kv_rows(&mono), &kv_rows(&forked)).expect("fingerprints");
        assert!(dev < 1e-5, "chunk={chunk}: forked+chunked K/V deviates by {dev}");
        let a = mono.generate(10, None, |_| true).expect("mono gen");
        let b = forked.generate(10, None, |_| true).expect("forked gen");
        assert_eq!(a.tokens, b.tokens, "chunk={chunk}: stream diverged after partial fork");
    }
}

fn coordinator(chunk_tokens: usize) -> Coordinator {
    let engine: Arc<dyn PrefillBackend> = Arc::new(SyntheticEngine::new(&[128, 256]));
    Coordinator::with_backend(
        engine,
        CoordinatorConfig {
            workers: 2,
            kv_pages: 1024,
            faults: None,
            chunk_tokens,
            ..Default::default()
        },
    )
}

/// Drive one generate through `coord` and return every branch's
/// `(tokens, finish)` in branch order.
fn streams(
    coord: &Coordinator,
    prompt: Vec<i32>,
    max_new: usize,
    fanout: usize,
) -> Vec<(Vec<i32>, Finish)> {
    let ts = coord
        .submit_generate_tickets(prompt, max_new, DecodePolicy::default(), fanout, None)
        .expect("submit must admit");
    ts.into_iter()
        .map(|mut t| {
            let r = t.recv_timeout(TERMINAL).expect("branch must reach a terminal outcome");
            (r.tokens, r.finish)
        })
        .collect()
}

#[test]
fn chunked_and_monolithic_coordinators_emit_identical_streams() {
    // chunk sizes: page-aligned, sub-page, and larger than every prompt
    for chunk in [16usize, 100, 1 << 20] {
        let mono = coordinator(0);
        let chunked = coordinator(chunk);
        for (len, fanout, max_new) in [(30usize, 1usize, 8usize), (150, 2, 6), (400, 3, 5)] {
            let prompt = prompt_from(len as u64 ^ 0x77, len);
            let a = streams(&mono, prompt.clone(), max_new, fanout);
            let b = streams(&chunked, prompt, max_new, fanout);
            assert_eq!(a, b, "chunk={chunk} len={len} fanout={fanout}: streams diverged");
        }
        // radix partial hit: a parked base, then base + divergent suffix
        // — in the chunked coordinator the suffix itself is chunked
        let base = prompt_from(0x5EED, 200);
        let mut extended = base.clone();
        extended.extend((0..90).map(|j| vocab::WORD0 + (j % 50) as i32));
        assert_eq!(
            streams(&mono, base.clone(), 4, 1),
            streams(&chunked, base.clone(), 4, 1),
            "chunk={chunk}: base streams diverged"
        );
        assert_eq!(
            streams(&mono, extended.clone(), 6, 2),
            streams(&chunked, extended, 6, 2),
            "chunk={chunk}: partial-hit streams diverged"
        );
    }
}

#[test]
fn deadline_expiring_mid_chunk_sheds_typed_and_unwinds() {
    // 8000-token prompt in page-sized chunks: ~500 chunk boundaries,
    // far more ingest work than the 1ms budget — the deadline must land
    // mid-ingest, shed typed, and unwind every resource
    let coord = coordinator(16);
    let kv = Arc::clone(coord.shared_kv());
    let admission = Arc::clone(coord.admission());
    let prompt = prompt_from(0xDEAD, 8000);
    let deadline = Instant::now() + Duration::from_millis(1);
    let ts = coord
        .submit_generate_tickets(prompt, 8, DecodePolicy::default(), 2, Some(deadline))
        .expect("submit must admit");
    for mut t in ts {
        match t.recv_timeout(TERMINAL) {
            // decode got far enough to emit a typed partial
            Ok(resp) => assert_eq!(
                resp.finish,
                Finish::DeadlineExceeded,
                "mid-ingest deadline must surface as a deadline finish"
            ),
            // shed at a chunk boundary (or at dispatch): typed error
            Err(e) => assert_eq!(
                e.downcast_ref::<ServeError>(),
                Some(&ServeError::DeadlineExceeded),
                "mid-ingest shed must be typed, got: {e:#}"
            ),
        }
    }
    drop(coord);
    assert_eq!(admission.outstanding(), (0, 0), "admission counters leaked");
    let (used, _, _) = kv.occupancy();
    assert_eq!(used, 0, "KV pages leaked");
    assert_eq!(kv.pages_resident(), 0, "KV slabs leaked");
    assert!(admission.outstanding_work_ns() < 1.0, "admission work estimate leaked");
}
