//! Figure 1 inset: metric-calculation overhead vs attention execution.
//!
//! Two measurements:
//!  (a) pure-rust reference pipeline, decomposed — pooling, OAM scoring,
//!      selection, sparse aggregation — to show the metric passes are a
//!      small fraction of execution (paper: 90 ms of 420 ms at 128K);
//!  (b) the compiled diag_stem module relative to prefill_dense as the
//!      whole-graph check.

use stem::sparse::{
    antidiag_scores, block_sparse_attention, block_sparse_attention_reference, oam_scores,
    select_stem, value_block_logmag, Tensor,
};
use stem::sparse::schedule::TpdConfig;
use stem::util::bench::{black_box, Bencher};
use stem::util::cli::Args;
use stem::util::rng::Rng;

fn main() {
    let args = Args::parse(std::env::args().skip(1), false);
    let quick = args.flag("quick");
    let threads = args.init_thread_pool();
    println!("sparse-core pool: {threads} threads (--threads / STEM_THREADS)");
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    let (h, hk, n, dh, block, stride) = (8usize, 4usize, 2048usize, 32usize, 64usize, 16usize);
    let mut rng = Rng::new(11);
    let q = Tensor::randn(&[h, n, dh], &mut rng);
    let k = Tensor::randn(&[hk, n, dh], &mut rng);
    let v = Tensor::randn(&[hk, n, dh], &mut rng);
    let cfg = TpdConfig { k_start: 6.4, mu: 0.7, ..Default::default() };

    println!("== metric overhead decomposition (pure-rust reference, N={n}) ==");
    let s_pool = bencher.run("pool: antidiag Q.K scores", || {
        black_box(antidiag_scores(&q, &k, block, stride));
    });
    s_pool.print();
    let s_mag = bencher.run("pool: value log-magnitude", || {
        black_box(value_block_logmag(&v, block));
    });
    s_mag.print();
    let s_oam = bencher.run("metric: OAM scores (pool + combine)", || {
        black_box(oam_scores(&q, &k, &v, block, stride, 0.2));
    });
    s_oam.print();
    let s_sel = bencher.run("select: OAM rank + TPD budget", || {
        black_box(select_stem(&q, &k, &v, block, stride, &cfg, 0.2));
    });
    s_sel.print();
    let sel = select_stem(&q, &k, &v, block, stride, &cfg, 0.2);
    let s_attn = bencher.run("exec: block-sparse attention (fused)", || {
        black_box(block_sparse_attention(&q, &k, &v, &sel, block));
    });
    s_attn.print();
    let s_attn_ref = bencher.run("exec: block-sparse attention (seed scalar)", || {
        black_box(block_sparse_attention_reference(&q, &k, &v, &sel, block));
    });
    s_attn_ref.print();
    println!(
        "fused kernel speedup vs seed scalar path: {:.2}x",
        s_attn_ref.median_ns / s_attn.median_ns
    );

    let metric_ms = s_oam.median_ns / 1e6;
    let exec_ms = s_attn.median_ns / 1e6;
    println!(
        "\nmetric/exec ratio: {:.1}% (paper at 128K: 90/330 = 27%; metric must not dominate)",
        100.0 * metric_ms / exec_ms
    );
    println!("budget fraction selected: {:.1}%", 100.0 * sel.budget_fraction());
}
