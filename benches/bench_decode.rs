//! Decode-phase micro-benchmarks: ns/token of the single-query kernels
//! (sparse selection + attention vs. dense full-context attention) across
//! cached-context lengths, the end-to-end paged session step, and the
//! speculative draft/verify loop vs sequential decode at equal output.
//! Writes machine-readable results to `BENCH_decode.json` so future PRs
//! have a decode perf trajectory (acceptance figures: sparse beating
//! dense ns/token at n >= 2048, and the `spec` section targeting ≥1.5×
//! tokens/sec at γ=4 over sequential dense decode — with the committed
//! stream asserted byte-identical). Session steps are measured once per
//! decode backend: `session_step_*` rows drive the TinyLm projection
//! core, `engine_step_*` rows drive compiled `decode_step` modules
//! through the engine-backed decode path.
//!
//!   cargo bench --bench bench_decode                 # full sizes
//!   cargo bench --bench bench_decode -- --quick      # small samples
//!   cargo bench --bench bench_decode -- --threads 1  # serial core

use std::sync::Arc;
use std::time::Instant;

use stem::coordinator::kv_cache::KvConfig;
use stem::decode::{
    DecodeBackend, DecodePolicy, DecodeSession, EngineBackend, SharedKv, SpecStats, TinyLm,
};
use stem::model::vocab;
use stem::runtime::SyntheticEngine;
use stem::sparse::simd::{self, SimdArm};
use stem::sparse::{
    decode_block_scores, decode_block_scores_with, select_decode, sparse_decode_attention,
    sparse_decode_attention_with, KvBlocks, Selection, Tensor, TensorKv,
};
use stem::util::bench::{black_box, stats_from, Bencher, Stats};
use stem::util::cli::Args;
use stem::util::json::Json;
use stem::util::rng::Rng;

struct Row {
    method: String,
    n: usize,
    ns_per_token: f64,
    /// vs the dense decode path at the same n; 0 = n/a
    speedup_vs_dense: f64,
}

fn row(st: &Stats, n: usize, speedup: f64) -> Row {
    Row { method: st.name.clone(), n, ns_per_token: st.median_ns, speedup_vs_dense: speedup }
}

fn main() {
    let args = Args::parse(std::env::args().skip(1), false);
    let quick = args.flag("quick");
    let threads = args.init_thread_pool();
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    let (h, hk, dh, block, stride, beta) = (8usize, 4usize, 32usize, 64usize, 8usize, 0.2f32);
    let sizes: &[usize] = if quick { &[512, 2048, 4096] } else { &[512, 1024, 2048, 4096, 8192] };
    let mut rows: Vec<Row> = vec![];

    for &n in sizes {
        let mut rng = Rng::new(9);
        let q = Tensor::randn(&[h, dh], &mut rng);
        let k = Tensor::randn(&[hk, n, dh], &mut rng);
        let v = Tensor::randn(&[hk, n, dh], &mut rng);
        let kv = TensorKv { k: &k, v: &v, n_tokens: n, block };
        let nblk = kv.n_blocks();
        let budget = ((nblk as f64 * 0.15) as usize).max(4);

        // dense decode: full selection through the same parallel kernel
        let full = Selection::decode_full(h, nblk);
        let s_dense = bencher.run(&format!("decode_dense n={n}"), || {
            black_box(sparse_decode_attention(&q, &kv, &full));
        });
        s_dense.print();
        rows.push(row(&s_dense, n, 1.0));

        // sparse decode: metric + selection + attention per step
        let s_sparse = bencher.run(&format!("decode_sparse n={n}"), || {
            let scores = decode_block_scores(&q, &kv, stride, beta);
            let sel = select_decode(&scores, budget, 1, 2);
            black_box(sparse_decode_attention(&q, &kv, &sel));
        });
        s_sparse.print();
        rows.push(row(&s_sparse, n, s_dense.median_ns / s_sparse.median_ns));
        println!(
            "  -> sparse/dense decode speedup at n={n}: {:.2}x (budget {}/{nblk} blocks, {threads} threads)\n",
            s_dense.median_ns / s_sparse.median_ns,
            budget.min(nblk)
        );
    }

    // --- simd: explicit-arm A/B over the vectorized decode kernels --------
    // one fixed cached context and a full selection, so the two arms
    // differ only in lane math; the CI bench-smoke gate reads these rows
    // and requires speedup >= 1.0 (target: >= 1.5x decode ns/token)
    let simd_n = if quick { 2048usize } else { 4096 };
    // (stage, n, scalar_ns, wide_ns)
    let mut simd_rows: Vec<(&'static str, usize, f64, f64)> = vec![];
    {
        let mut rng = Rng::new(21);
        let q = Tensor::randn(&[h, dh], &mut rng);
        let k = Tensor::randn(&[hk, simd_n, dh], &mut rng);
        let v = Tensor::randn(&[hk, simd_n, dh], &mut rng);
        let kv = TensorKv { k: &k, v: &v, n_tokens: simd_n, block };
        let full = Selection::decode_full(h, kv.n_blocks());

        let sc = bencher.run(&format!("simd=scalar decode_attention n={simd_n}"), || {
            black_box(sparse_decode_attention_with(SimdArm::Scalar, &q, &kv, &full));
        });
        sc.print();
        let wi = bencher.run(&format!("simd=wide decode_attention n={simd_n}"), || {
            black_box(sparse_decode_attention_with(SimdArm::Wide, &q, &kv, &full));
        });
        wi.print();
        simd_rows.push(("decode_attention", simd_n, sc.median_ns, wi.median_ns));

        let sc = bencher.run(&format!("simd=scalar decode_block_scores n={simd_n}"), || {
            black_box(decode_block_scores_with(SimdArm::Scalar, &q, &kv, stride, beta));
        });
        sc.print();
        let wi = bencher.run(&format!("simd=wide decode_block_scores n={simd_n}"), || {
            black_box(decode_block_scores_with(SimdArm::Wide, &q, &kv, stride, beta));
        });
        wi.print();
        simd_rows.push(("decode_block_scores", simd_n, sc.median_ns, wi.median_ns));
    }

    // end-to-end paged session steps (projections + paged append +
    // policy + kernel) at one representative context; the context grows
    // by one page per `block` steps, so we measure a fixed step count
    // by hand instead of letting the calibrated runner loop. Runs once
    // per decode backend: `session_step_*` rows are the TinyLm
    // projection core (the fast default), `engine_step_*` rows drive
    // the compiled-module path (here: the synthetic engine's
    // `decode_step` modules) — the real-model decode trajectory.
    let n0 = 2048usize;
    let steps = if quick { 16 } else { 64 };
    let backend_for = |engine: bool| -> Arc<dyn DecodeBackend> {
        if engine {
            let mut m = SyntheticEngine::tiny_model();
            m.n_heads = h;
            m.n_kv_heads = hk;
            m.d_head = dh;
            m.d_model = h * dh;
            m.block = block;
            let buckets = [512usize, 1024, 2048, 4096];
            let eng = Arc::new(SyntheticEngine::with_model(m, &buckets));
            Arc::new(EngineBackend::new(eng, "base").expect("synthetic decode modules"))
        } else {
            Arc::new(TinyLm::new(0xD0C0DE, h, hk, dh, vocab::VOCAB_SIZE))
        }
    };
    for (label, engine, policy) in [
        ("session_step_sparse", false, DecodePolicy { dense_below: 0, ..Default::default() }),
        ("session_step_dense", false, DecodePolicy::dense()),
        ("engine_step_sparse", true, DecodePolicy { dense_below: 0, ..Default::default() }),
        ("engine_step_dense", true, DecodePolicy::dense()),
    ] {
        let kvpool = SharedKv::new(KvConfig { total_pages: 1024, page_tokens: block }, hk, dh);
        let mut session = DecodeSession::new(kvpool, backend_for(engine), policy, 1).unwrap();
        let mut rng = Rng::new(11);
        let prompt: Vec<i32> =
            (0..n0).map(|_| vocab::WORD0 + rng.below(64) as i32).collect();
        session.prefill(&prompt).unwrap();
        let mut samples = Vec::with_capacity(steps);
        for _ in 0..steps {
            let t = Instant::now();
            black_box(session.step_once().unwrap());
            samples.push(t.elapsed().as_nanos() as f64);
        }
        let st = stats_from(&format!("{label} n={n0}"), samples);
        st.print();
        rows.push(row(&st, n0, 0.0));
    }

    // end-to-end TinyLm session step per SIMD arm: matvec projections +
    // the decode kernel both re-dispatch, so this is the ns/token figure
    // the >= 1.5x target speaks to. The global override is safe here —
    // bench mains are single-threaded drivers of the worker pool.
    {
        let mut measure = |arm: SimdArm| -> f64 {
            simd::set_override(Some(arm));
            let kvpool = SharedKv::new(KvConfig { total_pages: 1024, page_tokens: block }, hk, dh);
            let policy = DecodePolicy { dense_below: 0, ..Default::default() };
            let mut session = DecodeSession::new(kvpool, backend_for(false), policy, 1).unwrap();
            let mut rng = Rng::new(11);
            let prompt: Vec<i32> =
                (0..n0).map(|_| vocab::WORD0 + rng.below(64) as i32).collect();
            session.prefill(&prompt).unwrap();
            let mut samples = Vec::with_capacity(steps);
            for _ in 0..steps {
                let t = Instant::now();
                black_box(session.step_once().unwrap());
                samples.push(t.elapsed().as_nanos() as f64);
            }
            simd::set_override(None);
            let name = format!("simd={} session_step n={n0}", simd::arm_label(arm));
            let st = stats_from(&name, samples);
            st.print();
            st.median_ns
        };
        let sc = measure(SimdArm::Scalar);
        let wi = measure(SimdArm::Wide);
        simd_rows.push(("session_step", n0, sc, wi));
    }
    for &(stage, n, sc, wi) in &simd_rows {
        println!("  -> simd {stage} n={n}: {:.2}x ({})", sc / wi, simd::arm_label(SimdArm::Wide));
    }

    // --- speculative decode: draft/verify vs sequential, equal output --
    // Long-context dense serving is the regime speculation targets: the
    // serving attention is the dominant, memory-bound per-token cost,
    // and the batched verify streams the KV once per ROUND (γ+1 query
    // rows share the walk) instead of once per token, while drafts pay
    // only the tight sparse budget. Output equality is asserted hard;
    // the ≥1.5× γ=4 throughput target is reported (machine-dependent).
    let spec_n0 = if quick { 4096usize } else { 8192 };
    let spec_new = if quick { 32usize } else { 64 };
    let run_gen = |gamma: usize| -> (Vec<i32>, f64, SpecStats) {
        let kvpool = SharedKv::new(KvConfig { total_pages: 1024, page_tokens: block }, hk, dh);
        let model = Arc::new(TinyLm::new(0xD0C0DE, h, hk, dh, vocab::VOCAB_SIZE));
        let policy = DecodePolicy { spec_gamma: gamma, ..DecodePolicy::dense() };
        let mut session = DecodeSession::new(kvpool, model, policy, 1).unwrap();
        let mut rng = Rng::new(11);
        let prompt: Vec<i32> =
            (0..spec_n0).map(|_| vocab::WORD0 + rng.below(64) as i32).collect();
        session.prefill(&prompt).unwrap();
        let t = Instant::now();
        let stats = session.generate(spec_new, None, |_| true).unwrap();
        let wall = t.elapsed().as_nanos() as f64;
        assert_eq!(stats.steps, spec_new, "benchmark stream ended early");
        (stats.tokens, wall / stats.steps as f64, stats.spec)
    };
    let (seq_tokens, seq_ns, _) = run_gen(0);
    println!("spec baseline: sequential dense decode {seq_ns:.0} ns/token at n={spec_n0}");
    // (gamma, ns/token, speedup, acceptance, tokens/round)
    let mut spec_rows: Vec<(usize, f64, f64, f64, f64)> = vec![];
    for gamma in [2usize, 4] {
        let (tokens, ns, sp) = run_gen(gamma);
        assert_eq!(
            tokens, seq_tokens,
            "speculative decode must emit the exact sequential stream (gamma={gamma})"
        );
        let speedup = seq_ns / ns;
        println!(
            "spec gamma={gamma}: {ns:.0} ns/token ({speedup:.2}x), acceptance {:.0}%, {:.2} tokens/round",
            100.0 * sp.acceptance_rate(),
            sp.tokens_per_round(),
        );
        spec_rows.push((gamma, ns, speedup, sp.acceptance_rate(), sp.tokens_per_round()));
    }
    if let Some(&(_, _, s4, _, _)) = spec_rows.iter().find(|r| r.0 == 4) {
        println!(
            "  -> spec gate (gamma=4 tokens/sec >= 1.5x sequential): {}",
            if s4 >= 1.5 { "PASS" } else { "MISS" }
        );
    }

    let out = Json::obj(vec![
        ("bench", Json::Str("bench_decode".into())),
        ("threads", Json::Num(threads as f64)),
        ("quick", Json::Bool(quick)),
        (
            "geometry",
            Json::obj(vec![
                ("h", Json::Num(h as f64)),
                ("hk", Json::Num(hk as f64)),
                ("dh", Json::Num(dh as f64)),
                ("block", Json::Num(block as f64)),
                ("stride", Json::Num(stride as f64)),
            ]),
        ),
        (
            "results",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("method", Json::Str(r.method.clone())),
                            ("n", Json::Num(r.n as f64)),
                            ("ns_per_token", Json::Num(r.ns_per_token)),
                            ("speedup_vs_dense", Json::Num(r.speedup_vs_dense)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "spec",
            Json::obj(vec![
                ("n", Json::Num(spec_n0 as f64)),
                ("max_new", Json::Num(spec_new as f64)),
                ("serve", Json::Str("dense".into())),
                ("seq_ns_per_token", Json::Num(seq_ns)),
                ("target_speedup_gamma4", Json::Num(1.5)),
                (
                    "rows",
                    Json::Arr(
                        spec_rows
                            .iter()
                            .map(|&(gamma, ns, speedup, acc, tpr)| {
                                Json::obj(vec![
                                    ("gamma", Json::Num(gamma as f64)),
                                    ("ns_per_token", Json::Num(ns)),
                                    ("speedup_vs_sequential", Json::Num(speedup)),
                                    ("acceptance_rate", Json::Num(acc)),
                                    ("tokens_per_round", Json::Num(tpr)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        ),
        (
            "simd",
            Json::obj(vec![
                ("dispatch", Json::Str(simd::arm_label(SimdArm::Wide).into())),
                ("target_speedup", Json::Num(1.5)),
                (
                    "rows",
                    Json::Arr(
                        simd_rows
                            .iter()
                            .map(|&(stage, n, sc, wi)| {
                                Json::obj(vec![
                                    ("stage", Json::Str(stage.into())),
                                    ("n", Json::Num(n as f64)),
                                    ("scalar_ns", Json::Num(sc)),
                                    ("wide_ns", Json::Num(wi)),
                                    ("speedup", Json::Num(sc / wi)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        ),
    ]);
    let path = "BENCH_decode.json";
    match std::fs::write(path, format!("{out}")) {
        Ok(()) => println!("wrote {path} ({} result rows)", rows.len()),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
