//! L3 hot-path micro-benchmarks: batcher, admission, KV pool, schedule —
//! the coordinator-side costs that must stay negligible next to prefill
//! execution (target: < 5% of a 512-token prefill, i.e. well under 1 ms).

use std::time::{Duration, Instant};

use stem::coordinator::admission::{Admission, AdmissionConfig};
use stem::coordinator::batcher::{BatchKey, Batcher, BatcherConfig};
use stem::coordinator::kv_cache::{KvCache, KvConfig};
use stem::coordinator::{Method, PrefillRequest};
use stem::sparse::schedule::{block_budget_schedule, TpdConfig};
use stem::util::bench::{black_box, Bencher};

fn req(id: u64) -> PrefillRequest {
    PrefillRequest {
        id,
        checkpoint: "base".into(),
        method: Method::Dense,
        ids: vec![],
        diag: false,
        enqueued: Instant::now(),
        deadline: None,
    }
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };

    // batcher push/pop
    {
        let cfg = BatcherConfig { max_batch: 8, max_wait: Duration::from_millis(0) };
        let mut batcher = Batcher::new(cfg);
        let key = BatchKey { kind: "prefill_stem", bucket: 1024, checkpoint: "base".into() };
        let mut id = 0u64;
        let st = bencher.run("batcher: push 8 + drain", || {
            for _ in 0..8 {
                id += 1;
                batcher.push(key.clone(), req(id));
            }
            let mut got = 0;
            while let Some(b) = batcher.pop_ready(Instant::now()) {
                got += b.requests.len();
            }
            black_box(got);
        });
        st.print();
    }

    // admission control
    {
        let adm = Admission::new(AdmissionConfig::default());
        let st = bencher.run("admission: try_admit + release", || {
            let a = adm.try_admit(1024);
            black_box(&a);
            adm.release(1024);
        });
        st.print();
    }

    // admission with a cost-model work estimate attached (the new third
    // limit; must stay as cheap as the token-only path)
    {
        use stem::sim::{estimate_core_prefill_ns, Geometry, MethodCost};
        let g = Geometry { n_layers: 1, n_heads: 8, d_head: 32, d_model: 256, d_ff: 1024, block: 64 };
        let est = estimate_core_prefill_ns(
            &g,
            2048,
            MethodCost::Stem { k_start_blocks: 6.4, mu: 0.7 },
            4,
        );
        let adm = Admission::new(AdmissionConfig { max_work_ns: 1e12, ..Default::default() });
        let st = bencher.run("admission: try_admit_work + release_work", || {
            let a = adm.try_admit_work(1024, est);
            black_box(&a);
            adm.release_work(1024, est);
        });
        st.print();
    }

    // KV pool allocate/release
    {
        let mut kv = KvCache::new(KvConfig { total_pages: 4096, page_tokens: 64 });
        let mut id = 0u64;
        let st = bencher.run("kv: allocate+release 2048-token seq", || {
            id += 1;
            kv.allocate(id, 2048).unwrap();
            kv.release(id).unwrap();
            kv.drop_seq(id).unwrap();
        });
        st.print();
    }

    // TPD schedule computation (per-request cost in the scheduler)
    {
        let cfg = TpdConfig { k_start: 102.4, mu: 0.7, ..Default::default() };
        let st = bencher.run("schedule: 1024-block TPD budget vector", || {
            black_box(block_budget_schedule(1024, &cfg));
        });
        st.print();
    }
}
