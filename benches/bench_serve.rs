//! Overload-behavior macro-benchmark: the serving stack at 2x admission
//! capacity must fail *crisply* — every excess request shed immediately
//! with a typed error or deadline-expired, none hanging — while the
//! admitted share keeps flowing. Runs against the artifact-free
//! synthetic backend so CI exercises the full coordinator (admission →
//! dispatcher → prefix holders → decode lane) without PJRT.
//!
//! Hard gates (the `overload` section of `BENCH_serve.json`):
//!   * every submission reaches a terminal outcome (no hangs);
//!   * every non-admitted request fails typed at submission, and every
//!     deadline miss surfaces as `ServeError::DeadlineExceeded` or a
//!     `Finish::DeadlineExceeded` partial — never an opaque hang;
//!   * admitted throughput under 2x overload stays within 10% of the
//!     uncontended run (overload must not poison the admitted lane).
//!
//! The `telemetry_overhead` section runs the same uncontended workload
//! with the flight recorder armed + a snapshot poller (as `stem serve
//! --metrics-out` would run it) vs. tracing fully off, best-of-2 per
//! arm, and gates the traced/untraced admitted-throughput ratio at
//! >= 0.95 — observability may cost at most 5%. The traced run's final
//! snapshot is written to `metrics.json` for the CI schema check.
//!
//!   cargo bench --bench bench_serve              # full sizes
//!   cargo bench --bench bench_serve -- --quick   # small samples

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use stem::coordinator::admission::AdmissionConfig;
use stem::coordinator::{Coordinator, CoordinatorConfig, Finish};
use stem::decode::DecodePolicy;
use stem::runtime::{PrefillBackend, SyntheticEngine};
use stem::util::cli::Args;
use stem::util::json::Json;

/// Terminal-outcome bound: anything that takes this long under a
/// synthetic backend is a hang, not load.
const TERMINAL: Duration = Duration::from_secs(60);

fn coordinator(max_requests: usize, trace_events: usize) -> Coordinator {
    let engine: Arc<dyn PrefillBackend> = Arc::new(SyntheticEngine::new(&[128, 256]));
    Coordinator::with_backend(
        engine,
        CoordinatorConfig {
            workers: 4,
            kv_pages: 1024,
            trace_events,
            admission: AdmissionConfig {
                max_tokens: 1 << 20,
                max_requests,
                ..Default::default()
            },
            ..Default::default()
        },
    )
}

struct Phase {
    submitted: usize,
    completed: usize,
    shed_at_submit: usize,
    deadline_terminal: usize,
    errors: usize,
    tokens_out: usize,
    wall: Duration,
}

impl Phase {
    fn admitted_tokens_per_sec(&self) -> f64 {
        self.tokens_out as f64 / self.wall.as_secs_f64().max(1e-9)
    }
}

/// Push `n` generations through `coord` as fast as submission allows
/// and wait for every terminal outcome.
fn run_phase(coord: &Coordinator, n: usize, max_new: usize) -> Phase {
    let t0 = Instant::now();
    let mut tickets = Vec::new();
    let mut shed_at_submit = 0usize;
    for i in 0..n {
        // distinct prompts: no prefix reuse, every request pays ingest
        let prompt: Vec<i32> = (0..16).map(|j| 16 + ((i * 7 + j) % 64) as i32).collect();
        match coord.submit_generate_tickets(prompt, max_new, DecodePolicy::default(), 1, None) {
            Ok(ts) => tickets.extend(ts),
            Err(_) => shed_at_submit += 1,
        }
    }
    let mut completed = 0usize;
    let mut deadline_terminal = 0usize;
    let mut errors = 0usize;
    let mut tokens_out = 0usize;
    for mut t in tickets {
        match t.recv_timeout(TERMINAL) {
            Ok(resp) => {
                tokens_out += resp.tokens.len();
                match resp.finish {
                    Finish::Complete => completed += 1,
                    Finish::DeadlineExceeded => deadline_terminal += 1,
                    Finish::Cancelled => errors += 1,
                }
            }
            Err(e) if e.to_string().contains("timed out") => {
                panic!("request hung past {TERMINAL:?} — overload must shed, not stall")
            }
            Err(_) => errors += 1,
        }
    }
    Phase {
        submitted: n,
        completed,
        shed_at_submit,
        deadline_terminal,
        errors,
        tokens_out,
        wall: t0.elapsed(),
    }
}

fn phase_json(p: &Phase) -> Json {
    Json::obj(vec![
        ("submitted", Json::Num(p.submitted as f64)),
        ("completed", Json::Num(p.completed as f64)),
        ("shed_at_submit", Json::Num(p.shed_at_submit as f64)),
        ("deadline_terminal", Json::Num(p.deadline_terminal as f64)),
        ("errors", Json::Num(p.errors as f64)),
        ("tokens_out", Json::Num(p.tokens_out as f64)),
        ("wall_ns", Json::Num(p.wall.as_nanos() as f64)),
        ("admitted_tokens_per_sec", Json::Num(p.admitted_tokens_per_sec())),
    ])
}

/// One telemetry arm: the uncontended workload with `trace_events`
/// ring slots, a snapshot poller running alongside (as `stem serve
/// --metrics-out` would), returning the phase and — when tracing is on
/// — the final snapshot JSON for the `metrics.json` artifact.
fn run_telemetry_arm(trace_events: usize, n: usize, max_new: usize) -> (Phase, Option<Json>) {
    let coord = coordinator(4 * n, trace_events);
    let stop = AtomicBool::new(false);
    let mut phase = None;
    std::thread::scope(|s| {
        s.spawn(|| {
            while !stop.load(Ordering::Relaxed) {
                let _ = coord.snapshot();
                std::thread::sleep(Duration::from_millis(5));
            }
        });
        phase = Some(run_phase(&coord, n, max_new));
        stop.store(true, Ordering::Relaxed);
    });
    let snap = (trace_events > 0).then(|| coord.snapshot().to_json());
    (phase.expect("scoped phase ran"), snap)
}

fn main() {
    let args = Args::from_env(false);
    let quick = args.flag("quick");
    let capacity = if quick { 8 } else { 16 };
    let n = if quick { 32 } else { 96 };
    let max_new = if quick { 16 } else { 32 };

    // uncontended: same workload, admission ceiling far above it
    let uncontended = {
        let coord = coordinator(4 * n, 4096);
        run_phase(&coord, n, max_new)
    };
    // overload: ceiling at `capacity` outstanding, 2x that submitted in
    // a burst — excess must shed typed at submission (retryable), the
    // admitted share must keep its throughput
    let overload = {
        let coord = coordinator(capacity, 4096);
        run_phase(&coord, n, max_new)
    };

    // telemetry overhead: tracing + snapshot polling on vs fully off,
    // best-of-2 per arm to damp scheduler noise
    let best_of_2 = |trace_events: usize| {
        let (a, ja) = run_telemetry_arm(trace_events, n, max_new);
        let (b, jb) = run_telemetry_arm(trace_events, n, max_new);
        if a.admitted_tokens_per_sec() >= b.admitted_tokens_per_sec() {
            (a, ja)
        } else {
            (b, jb)
        }
    };
    let (traced, metrics_json) = best_of_2(4096);
    let (untraced, _) = best_of_2(0);

    // gates -----------------------------------------------------------
    assert_eq!(
        uncontended.completed, uncontended.submitted,
        "uncontended run must complete everything"
    );
    assert_eq!(overload.errors, 0, "overload produced non-typed failures");
    assert_eq!(
        overload.completed + overload.shed_at_submit + overload.deadline_terminal,
        overload.submitted,
        "every overloaded request must be terminal: completed, typed-shed or expired"
    );
    assert!(
        overload.shed_at_submit > 0,
        "2x capacity must actually shed (capacity {capacity}, submitted {n})"
    );
    let ratio = overload.admitted_tokens_per_sec() / uncontended.admitted_tokens_per_sec();
    println!(
        "uncontended: {} reqs, {:.0} tok/s | overload(cap {capacity}): {} completed, {} shed, \
         {:.0} tok/s | admitted-throughput ratio {ratio:.3} (gate >= 0.9)",
        uncontended.completed,
        uncontended.admitted_tokens_per_sec(),
        overload.completed,
        overload.shed_at_submit,
        overload.admitted_tokens_per_sec(),
    );
    assert!(
        ratio >= 0.9,
        "admitted throughput collapsed under overload: {ratio:.3} < 0.9"
    );

    // telemetry gates: both arms complete everything; tracing costs at
    // most 5% of admitted throughput
    assert_eq!(traced.completed, traced.submitted, "traced arm must complete everything");
    assert_eq!(untraced.completed, untraced.submitted, "untraced arm must complete everything");
    let tel_ratio = traced.admitted_tokens_per_sec() / untraced.admitted_tokens_per_sec();
    println!(
        "telemetry: traced {:.0} tok/s, untraced {:.0} tok/s | ratio {tel_ratio:.3} (gate >= 0.95)",
        traced.admitted_tokens_per_sec(),
        untraced.admitted_tokens_per_sec(),
    );
    assert!(tel_ratio >= 0.95, "tracing overhead above 5%: ratio {tel_ratio:.3} < 0.95");
    if let Some(j) = &metrics_json {
        let path = "metrics.json";
        match std::fs::write(path, format!("{j}\n")) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }

    let out = Json::obj(vec![
        (
            "config",
            Json::obj(vec![
                ("quick", Json::Bool(quick)),
                ("capacity", Json::Num(capacity as f64)),
                ("requests", Json::Num(n as f64)),
                ("max_new", Json::Num(max_new as f64)),
            ]),
        ),
        (
            "overload",
            Json::obj(vec![
                ("uncontended", phase_json(&uncontended)),
                ("overload_2x", phase_json(&overload)),
                ("admitted_throughput_ratio", Json::Num(ratio)),
            ]),
        ),
        (
            "telemetry_overhead",
            Json::obj(vec![
                ("traced", phase_json(&traced)),
                ("untraced", phase_json(&untraced)),
                ("admitted_throughput_ratio", Json::Num(tel_ratio)),
            ]),
        ),
    ]);
    let path = "BENCH_serve.json";
    match std::fs::write(path, format!("{out}")) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
