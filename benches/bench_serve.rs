//! Overload-behavior macro-benchmark: the serving stack at 2x admission
//! capacity must fail *crisply* — every excess request shed immediately
//! with a typed error or deadline-expired, none hanging — while the
//! admitted share keeps flowing. Runs against the artifact-free
//! synthetic backend so CI exercises the full coordinator (admission →
//! dispatcher → prefix holders → decode lane) without PJRT.
//!
//! Hard gates (the `overload` section of `BENCH_serve.json`):
//!   * every submission reaches a terminal outcome (no hangs);
//!   * every non-admitted request fails typed at submission, and every
//!     deadline miss surfaces as `ServeError::DeadlineExceeded` or a
//!     `Finish::DeadlineExceeded` partial — never an opaque hang;
//!   * admitted throughput under 2x overload stays within 10% of the
//!     uncontended run (overload must not poison the admitted lane).
//!
//! The `telemetry_overhead` section runs the same uncontended workload
//! with the flight recorder armed + a snapshot poller (as `stem serve
//! --metrics-out` would run it) vs. tracing fully off, best-of-2 per
//! arm, and gates the traced/untraced admitted-throughput ratio at
//! >= 0.95 — observability may cost at most 5%. The traced run's final
//! snapshot is written to `metrics.json` for the CI schema check.
//!
//! The `latency` section is the chunked-ingest load harness:
//!
//!   * **head-of-line gate** — a single-worker coordinator ingests one
//!     64K-token prompt while a burst of short generates queues behind
//!     it, once monolithic (`chunk_tokens = 0`) and once chunked. Short
//!     p99 latency must improve >= 3x chunked vs monolithic while
//!     admitted goodput stays within 10% (chunking must not tax
//!     throughput for its latency win);
//!   * **synthesized traffic** — an open-loop `workload::synthesize`
//!     trace (bursty arrivals, heavy-tailed lognormal prompt/output
//!     lengths, fan-out families, tenant deadlines) driven through the
//!     chunked coordinator, reporting TTFT/TPOT p50/p99 from the
//!     coordinator's histograms plus goodput and shed counts.
//!
//!   cargo bench --bench bench_serve              # full sizes
//!   cargo bench --bench bench_serve -- --quick   # small samples

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use stem::coordinator::admission::AdmissionConfig;
use stem::coordinator::{Coordinator, CoordinatorConfig, Finish};
use stem::decode::DecodePolicy;
use stem::obs::MetricsSnapshot;
use stem::runtime::{PrefillBackend, SyntheticEngine};
use stem::util::cli::Args;
use stem::util::json::Json;
use stem::workload::{synthesize, ArrivalModel, LengthModel, TenantClass, TrafficConfig};

/// Terminal-outcome bound: anything that takes this long under a
/// synthetic backend is a hang, not load.
const TERMINAL: Duration = Duration::from_secs(60);

fn coordinator(max_requests: usize, trace_events: usize) -> Coordinator {
    let engine: Arc<dyn PrefillBackend> = Arc::new(SyntheticEngine::new(&[128, 256]));
    Coordinator::with_backend(
        engine,
        CoordinatorConfig {
            workers: 4,
            kv_pages: 1024,
            trace_events,
            admission: AdmissionConfig {
                max_tokens: 1 << 20,
                max_requests,
                ..Default::default()
            },
            ..Default::default()
        },
    )
}

struct Phase {
    submitted: usize,
    completed: usize,
    shed_at_submit: usize,
    deadline_terminal: usize,
    errors: usize,
    tokens_out: usize,
    wall: Duration,
}

impl Phase {
    fn admitted_tokens_per_sec(&self) -> f64 {
        self.tokens_out as f64 / self.wall.as_secs_f64().max(1e-9)
    }
}

/// Push `n` generations through `coord` as fast as submission allows
/// and wait for every terminal outcome.
fn run_phase(coord: &Coordinator, n: usize, max_new: usize) -> Phase {
    let t0 = Instant::now();
    let mut tickets = Vec::new();
    let mut shed_at_submit = 0usize;
    for i in 0..n {
        // distinct prompts: no prefix reuse, every request pays ingest
        let prompt: Vec<i32> = (0..16).map(|j| 16 + ((i * 7 + j) % 64) as i32).collect();
        match coord.submit_generate_tickets(prompt, max_new, DecodePolicy::default(), 1, None) {
            Ok(ts) => tickets.extend(ts),
            Err(_) => shed_at_submit += 1,
        }
    }
    let mut completed = 0usize;
    let mut deadline_terminal = 0usize;
    let mut errors = 0usize;
    let mut tokens_out = 0usize;
    for mut t in tickets {
        match t.recv_timeout(TERMINAL) {
            Ok(resp) => {
                tokens_out += resp.tokens.len();
                match resp.finish {
                    Finish::Complete => completed += 1,
                    Finish::DeadlineExceeded => deadline_terminal += 1,
                    Finish::Cancelled => errors += 1,
                }
            }
            Err(e) if e.to_string().contains("timed out") => {
                panic!("request hung past {TERMINAL:?} — overload must shed, not stall")
            }
            Err(_) => errors += 1,
        }
    }
    Phase {
        submitted: n,
        completed,
        shed_at_submit,
        deadline_terminal,
        errors,
        tokens_out,
        wall: t0.elapsed(),
    }
}

fn phase_json(p: &Phase) -> Json {
    Json::obj(vec![
        ("submitted", Json::Num(p.submitted as f64)),
        ("completed", Json::Num(p.completed as f64)),
        ("shed_at_submit", Json::Num(p.shed_at_submit as f64)),
        ("deadline_terminal", Json::Num(p.deadline_terminal as f64)),
        ("errors", Json::Num(p.errors as f64)),
        ("tokens_out", Json::Num(p.tokens_out as f64)),
        ("wall_ns", Json::Num(p.wall.as_nanos() as f64)),
        ("admitted_tokens_per_sec", Json::Num(p.admitted_tokens_per_sec())),
    ])
}

/// One telemetry arm: the uncontended workload with `trace_events`
/// ring slots, a snapshot poller running alongside (as `stem serve
/// --metrics-out` would), returning the phase and — when tracing is on
/// — the final snapshot JSON for the `metrics.json` artifact.
fn run_telemetry_arm(trace_events: usize, n: usize, max_new: usize) -> (Phase, Option<Json>) {
    let coord = coordinator(4 * n, trace_events);
    let stop = AtomicBool::new(false);
    let mut phase = None;
    std::thread::scope(|s| {
        s.spawn(|| {
            while !stop.load(Ordering::Relaxed) {
                let _ = coord.snapshot();
                std::thread::sleep(Duration::from_millis(5));
            }
        });
        phase = Some(run_phase(&coord, n, max_new));
        stop.store(true, Ordering::Relaxed);
    });
    let snap = (trace_events > 0).then(|| coord.snapshot().to_json());
    (phase.expect("scoped phase ran"), snap)
}

/// Sorted-latency percentile (nearest-rank on the client-observed walls).
fn pctl(sorted: &[Duration], p: f64) -> Duration {
    sorted[((sorted.len() - 1) as f64 * p).round() as usize]
}

/// One head-of-line arm: a single-worker coordinator, one huge prompt
/// ingest submitted first, then a burst of short generates that queue
/// behind it. Returns the shorts' client-observed wall latencies
/// (sorted) and the arm's admitted goodput in tokens/sec. With
/// `chunk_tokens = 0` the ingest is monolithic and the shorts eat the
/// full head-of-line stall; chunked, they cut in at chunk boundaries.
fn hol_arm(chunk_tokens: usize, long_tokens: usize, shorts: usize) -> (Vec<Duration>, f64) {
    let engine: Arc<dyn PrefillBackend> = Arc::new(SyntheticEngine::new(&[128, 256]));
    let coord = Coordinator::with_backend(
        engine,
        CoordinatorConfig {
            workers: 1,
            kv_pages: 2048,
            chunk_tokens,
            admission: AdmissionConfig {
                max_tokens: 1 << 22,
                max_requests: 256,
                ..Default::default()
            },
            ..Default::default()
        },
    );
    let t0 = Instant::now();
    let long_prompt: Vec<i32> = (0..long_tokens).map(|j| 16 + (j % 64) as i32).collect();
    let long_tickets = coord
        .submit_generate_tickets(long_prompt, 8, DecodePolicy::default(), 1, None)
        .expect("long ingest must admit");
    let mut lats = Vec::new();
    let mut tokens = 0usize;
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for i in 0..shorts {
            let prompt: Vec<i32> = (0..16).map(|j| 16 + ((i * 11 + j) % 64) as i32).collect();
            let submitted = Instant::now();
            let ts = coord
                .submit_generate_tickets(prompt, 4, DecodePolicy::default(), 1, None)
                .expect("short generate must admit");
            for mut t in ts {
                handles.push(s.spawn(move || {
                    let resp = t.recv_timeout(TERMINAL).expect("short must reach terminal");
                    (submitted.elapsed(), resp.tokens.len())
                }));
            }
        }
        for h in handles {
            let (lat, n) = h.join().expect("latency thread");
            lats.push(lat);
            tokens += n;
        }
    });
    for mut t in long_tickets {
        let resp = t.recv_timeout(TERMINAL).expect("long ingest must complete");
        tokens += resp.tokens.len();
    }
    let wall = t0.elapsed();
    lats.sort();
    (lats, tokens as f64 / wall.as_secs_f64().max(1e-9))
}

struct LoadResult {
    completed: usize,
    shed: usize,
    tokens_out: usize,
    wall: Duration,
    snap: MetricsSnapshot,
}

/// Drive a synthesized open-loop trace (bursty arrivals, heavy-tailed
/// lognormal lengths, fan-out families, tenant deadlines) through a
/// chunked-ingest coordinator. Branch outcomes are counted client-side;
/// TTFT/TPOT come from the coordinator's own histograms afterwards.
fn run_load_harness(quick: bool) -> LoadResult {
    let cfg = TrafficConfig {
        seed: 42,
        n_requests: if quick { 24 } else { 64 },
        arrivals: ArrivalModel::Bursty { rps: if quick { 48.0 } else { 24.0 }, burst: 4.0 },
        prompt_len: LengthModel {
            log_mean: 5.0,
            log_sigma: 1.0,
            min: 16,
            cap: if quick { 512 } else { 1024 },
        },
        output_len: LengthModel {
            log_mean: 2.3,
            log_sigma: 0.7,
            min: 2,
            cap: if quick { 12 } else { 24 },
        },
        fanout_weights: vec![(1, 0.85), (2, 0.10), (4, 0.05)],
        tenants: vec![
            TenantClass { weight: 0.75, deadline_ms: None },
            TenantClass { weight: 0.25, deadline_ms: Some(if quick { 250 } else { 400 }) },
        ],
    };
    let trace = synthesize(&cfg);
    let engine: Arc<dyn PrefillBackend> = Arc::new(SyntheticEngine::new(&[128, 256]));
    let coord = Coordinator::with_backend(
        engine,
        CoordinatorConfig {
            workers: 2,
            kv_pages: 2048,
            chunk_tokens: 256,
            admission: AdmissionConfig {
                max_tokens: 48 * 1024,
                max_requests: 64,
                ..Default::default()
            },
            ..Default::default()
        },
    );
    let start = Instant::now();
    let mut tickets = Vec::new();
    let mut shed = 0usize;
    for (i, r) in trace.iter().enumerate() {
        let now = start.elapsed();
        if r.at > now {
            std::thread::sleep(r.at - now);
        }
        let prompt: Vec<i32> =
            (0..r.prompt_tokens).map(|j| 16 + ((i * 13 + j) % 64) as i32).collect();
        let deadline = r.deadline_ms.map(|ms| Instant::now() + Duration::from_millis(ms));
        let sub = coord.submit_generate_tickets(
            prompt,
            r.max_new,
            DecodePolicy::default(),
            r.fanout,
            deadline,
        );
        match sub {
            Ok(ts) => tickets.extend(ts),
            // admission shed at submit: typed, retryable, counts against
            // goodput but is exactly what overload should produce
            Err(_) => shed += 1,
        }
    }
    let mut completed = 0usize;
    let mut tokens_out = 0usize;
    for mut t in tickets {
        match t.recv_timeout(TERMINAL) {
            Ok(resp) => match resp.finish {
                Finish::Complete => {
                    completed += 1;
                    tokens_out += resp.tokens.len();
                }
                // deadline/cancel partials are not goodput
                Finish::DeadlineExceeded | Finish::Cancelled => shed += 1,
            },
            Err(e) if e.to_string().contains("timed out") => {
                panic!("load-harness request hung past {TERMINAL:?}")
            }
            // typed failures (e.g. deadline expired before start)
            Err(_) => shed += 1,
        }
    }
    let wall = start.elapsed();
    let snap = coord.snapshot();
    LoadResult { completed, shed, tokens_out, wall, snap }
}

fn main() {
    let args = Args::from_env(false);
    let quick = args.flag("quick");
    let capacity = if quick { 8 } else { 16 };
    let n = if quick { 32 } else { 96 };
    let max_new = if quick { 16 } else { 32 };

    // uncontended: same workload, admission ceiling far above it
    let uncontended = {
        let coord = coordinator(4 * n, 4096);
        run_phase(&coord, n, max_new)
    };
    // overload: ceiling at `capacity` outstanding, 2x that submitted in
    // a burst — excess must shed typed at submission (retryable), the
    // admitted share must keep its throughput
    let overload = {
        let coord = coordinator(capacity, 4096);
        run_phase(&coord, n, max_new)
    };

    // telemetry overhead: tracing + snapshot polling on vs fully off,
    // best-of-2 per arm to damp scheduler noise
    let best_of_2 = |trace_events: usize| {
        let (a, ja) = run_telemetry_arm(trace_events, n, max_new);
        let (b, jb) = run_telemetry_arm(trace_events, n, max_new);
        if a.admitted_tokens_per_sec() >= b.admitted_tokens_per_sec() {
            (a, ja)
        } else {
            (b, jb)
        }
    };
    let (traced, metrics_json) = best_of_2(4096);
    let (untraced, _) = best_of_2(0);

    // gates -----------------------------------------------------------
    assert_eq!(
        uncontended.completed, uncontended.submitted,
        "uncontended run must complete everything"
    );
    assert_eq!(overload.errors, 0, "overload produced non-typed failures");
    assert_eq!(
        overload.completed + overload.shed_at_submit + overload.deadline_terminal,
        overload.submitted,
        "every overloaded request must be terminal: completed, typed-shed or expired"
    );
    assert!(
        overload.shed_at_submit > 0,
        "2x capacity must actually shed (capacity {capacity}, submitted {n})"
    );
    let ratio = overload.admitted_tokens_per_sec() / uncontended.admitted_tokens_per_sec();
    println!(
        "uncontended: {} reqs, {:.0} tok/s | overload(cap {capacity}): {} completed, {} shed, \
         {:.0} tok/s | admitted-throughput ratio {ratio:.3} (gate >= 0.9)",
        uncontended.completed,
        uncontended.admitted_tokens_per_sec(),
        overload.completed,
        overload.shed_at_submit,
        overload.admitted_tokens_per_sec(),
    );
    assert!(
        ratio >= 0.9,
        "admitted throughput collapsed under overload: {ratio:.3} < 0.9"
    );

    // telemetry gates: both arms complete everything; tracing costs at
    // most 5% of admitted throughput
    assert_eq!(traced.completed, traced.submitted, "traced arm must complete everything");
    assert_eq!(untraced.completed, untraced.submitted, "untraced arm must complete everything");
    let tel_ratio = traced.admitted_tokens_per_sec() / untraced.admitted_tokens_per_sec();
    println!(
        "telemetry: traced {:.0} tok/s, untraced {:.0} tok/s | ratio {tel_ratio:.3} (gate >= 0.95)",
        traced.admitted_tokens_per_sec(),
        untraced.admitted_tokens_per_sec(),
    );
    assert!(tel_ratio >= 0.95, "tracing overhead above 5%: ratio {tel_ratio:.3} < 0.95");

    // chunked-ingest head-of-line gate: one concurrent 64K-token ingest,
    // short decode latency p99 must improve >= 3x chunked vs monolithic
    // while admitted goodput stays within 10%
    let long_tokens = if quick { 32 * 1024 } else { 64 * 1024 };
    let hol_chunk = if quick { 1024 } else { 2048 };
    let hol_shorts = 12;
    let (mono_lats, mono_goodput) = hol_arm(0, long_tokens, hol_shorts);
    let (chunk_lats, chunk_goodput) = hol_arm(hol_chunk, long_tokens, hol_shorts);
    let mono_p99_us = pctl(&mono_lats, 0.99).as_secs_f64() * 1e6;
    let chunk_p99_us = (pctl(&chunk_lats, 0.99).as_secs_f64() * 1e6).max(1.0);
    let hol_ratio = mono_p99_us / chunk_p99_us;
    let hol_goodput_ratio = chunk_goodput / mono_goodput.max(1e-9);
    println!(
        "hol({long_tokens}-token ingest, chunk {hol_chunk}): short p99 mono {:.1}ms vs chunked \
         {:.1}ms | ratio {hol_ratio:.1} (gate >= 3) | goodput ratio {hol_goodput_ratio:.3} \
         (gate >= 0.9)",
        mono_p99_us / 1e3,
        chunk_p99_us / 1e3,
    );
    assert!(
        hol_ratio >= 3.0,
        "chunked ingest must cut head-of-line p99 >= 3x: mono {mono_p99_us:.0}us vs chunked \
         {chunk_p99_us:.0}us (ratio {hol_ratio:.2})"
    );
    assert!(
        hol_goodput_ratio >= 0.9,
        "chunking taxed goodput more than 10%: ratio {hol_goodput_ratio:.3} < 0.9"
    );

    // synthesized-traffic load harness: TTFT/TPOT histograms + goodput
    let load = run_load_harness(quick);
    let ttft = &load.snap.gen_ttft;
    let tpot = &load.snap.tpot;
    let goodput = load.tokens_out as f64 / load.wall.as_secs_f64().max(1e-9);
    println!(
        "load harness: {} branches completed, {} shed | ttft p50 {}us p99 {}us | tpot p50 {}us \
         p99 {}us | goodput {goodput:.0} tok/s",
        load.completed,
        load.shed,
        ttft.p50_us,
        ttft.p99_us,
        tpot.p50_us,
        tpot.p99_us,
    );
    assert!(load.completed > 0, "load harness completed nothing");
    assert!(ttft.count > 0 && tpot.count > 0, "latency histograms must be populated");
    assert!(ttft.p50_us <= ttft.p99_us && tpot.p50_us <= tpot.p99_us, "p50/p99 monotonicity");

    if let Some(j) = &metrics_json {
        let path = "metrics.json";
        match std::fs::write(path, format!("{j}\n")) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
    }

    let out = Json::obj(vec![
        (
            "config",
            Json::obj(vec![
                ("quick", Json::Bool(quick)),
                ("capacity", Json::Num(capacity as f64)),
                ("requests", Json::Num(n as f64)),
                ("max_new", Json::Num(max_new as f64)),
            ]),
        ),
        (
            "overload",
            Json::obj(vec![
                ("uncontended", phase_json(&uncontended)),
                ("overload_2x", phase_json(&overload)),
                ("admitted_throughput_ratio", Json::Num(ratio)),
            ]),
        ),
        (
            "telemetry_overhead",
            Json::obj(vec![
                ("traced", phase_json(&traced)),
                ("untraced", phase_json(&untraced)),
                ("admitted_throughput_ratio", Json::Num(tel_ratio)),
            ]),
        ),
        (
            "latency",
            Json::obj(vec![
                (
                    "ttft_us",
                    Json::obj(vec![
                        ("p50", Json::Num(ttft.p50_us as f64)),
                        ("p99", Json::Num(ttft.p99_us as f64)),
                        ("count", Json::Num(ttft.count as f64)),
                    ]),
                ),
                (
                    "tpot_us",
                    Json::obj(vec![
                        ("p50", Json::Num(tpot.p50_us as f64)),
                        ("p99", Json::Num(tpot.p99_us as f64)),
                        ("count", Json::Num(tpot.count as f64)),
                    ]),
                ),
                ("goodput_tok_per_s", Json::Num(goodput)),
                ("completed", Json::Num(load.completed as f64)),
                ("shed", Json::Num(load.shed as f64)),
                (
                    "hol_gate",
                    Json::obj(vec![
                        ("long_tokens", Json::Num(long_tokens as f64)),
                        ("chunk_tokens", Json::Num(hol_chunk as f64)),
                        ("monolithic_p99_us", Json::Num(mono_p99_us)),
                        ("chunked_p99_us", Json::Num(chunk_p99_us)),
                        ("ratio", Json::Num(hol_ratio)),
                        ("monolithic_goodput", Json::Num(mono_goodput)),
                        ("chunked_goodput", Json::Num(chunk_goodput)),
                        ("goodput_ratio", Json::Num(hol_goodput_ratio)),
                    ]),
                ),
            ]),
        ),
    ]);
    let path = "BENCH_serve.json";
    match std::fs::write(path, format!("{out}")) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
