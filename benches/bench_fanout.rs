//! Shared-prefix fan-out macro-benchmark: serve N continuations of one
//! prompt as (a) forks of a single ingested prefix (refcounted pages,
//! CoW divergence) and (b) N fully independent sessions, and compare
//! aggregate ns/token and page residency. Also re-checks the decode
//! parity acceptance on forked state: the full-budget decode kernel must
//! match the scalar dense oracle to 1e-5 on every branch's view after it
//! has diverged. Writes machine-readable results to `BENCH_fanout.json`
//! (targets: >= 2x page savings, >= 1.5x aggregate throughput at
//! fanout 4).
//!
//! Second act — token-granular prefix reuse: a family of prompts sharing
//! a 75% token prefix is served through a session-level prefix cache in
//! both `--prefix-mode` disciplines. Exact mode re-ingests every
//! distinct prompt in full; radix mode forks the page-aligned covered
//! prefix off the best cached holder ([`stem::coordinator::RadixIndex`])
//! and ingests only the suffix. Hard gates: >= 1.5x fewer prompt-ingest
//! tokens in radix mode, identical branch token streams across modes,
//! and 1e-5 dense-oracle parity on every reused branch's view.
//!
//!   cargo bench --bench bench_fanout                 # full sizes
//!   cargo bench --bench bench_fanout -- --quick      # small samples
//!   cargo bench --bench bench_fanout -- --fanout 8

use std::sync::Arc;
use std::time::Instant;

use stem::coordinator::kv_cache::KvConfig;
use stem::coordinator::RadixIndex;
use stem::decode::{
    decode_attend, decode_attend_dense_reference, DecodePolicy, DecodeSession, SharedKv, TinyLm,
};
use stem::model::vocab;
use stem::sparse::Tensor;
use stem::util::cli::Args;
use stem::util::json::Json;
use stem::util::rng::Rng;

const BLOCK: usize = 64;
const GEO: (usize, usize, usize) = (8, 4, 32); // (h, hk, dh)

struct ModeResult {
    wall_ns: u64,
    tokens: usize,
    pages_used: usize,
    slab_pages: usize,
}

impl ModeResult {
    fn ns_per_token(&self) -> f64 {
        self.wall_ns as f64 / self.tokens.max(1) as f64
    }
}

fn policy(max_new: usize) -> DecodePolicy {
    DecodePolicy { dense_below: 1024, k_start: 8.0, horizon: max_new.max(1), ..Default::default() }
}

fn prompt(len: usize) -> Vec<i32> {
    let mut r = Rng::new(42);
    let mut p = vec![vocab::BOS];
    p.extend((1..len).map(|_| vocab::WORD0 + r.below(64) as i32));
    p
}

fn pool(pages: usize) -> Arc<SharedKv> {
    let (_, hk, dh) = GEO;
    SharedKv::new(KvConfig { total_pages: pages, page_tokens: BLOCK }, hk, dh)
}

fn model() -> Arc<TinyLm> {
    let (h, hk, dh) = GEO;
    Arc::new(TinyLm::new(0xD0C0DE, h, hk, dh, vocab::VOCAB_SIZE))
}

/// Full-budget decode kernel vs. scalar dense oracle on a session's
/// current (possibly forked/CoW'd) view; returns the max abs deviation.
fn parity_diff(session: &DecodeSession) -> f32 {
    let m = session.model();
    let (q, _, _) = m.project(session.last_token(), session.n_ctx(), true);
    let q = Tensor::from_vec(&[m.h, m.dh], q.expect("with_q"));
    session
        .with_kv_view(|view| {
            let att = decode_attend(&q, view, &DecodePolicy::dense(), 0);
            let oracle = decode_attend_dense_reference(&q, view);
            att.out
                .iter()
                .zip(&oracle)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max)
        })
        .expect("kv view")
}

/// One ingest + `fanout` forked branches, each steered by a divergence
/// token then decoded `max_new` steps. Returns the mode stats and the
/// worst parity deviation across branches (checked *after* divergence so
/// CoW'd tails and grown pages are covered).
fn run_forked(p: &[i32], fanout: usize, max_new: usize) -> (ModeResult, f32) {
    let kv = pool(4096);
    let t0 = Instant::now();
    let mut root = DecodeSession::new(Arc::clone(&kv), model(), policy(max_new), 1).unwrap();
    root.prefill(p).unwrap();
    let mut branches: Vec<DecodeSession> = Vec::with_capacity(fanout);
    for i in 0..fanout {
        let mut b = root.fork(2 + i as u64).unwrap();
        b.prefill(&[vocab::WORD0 + (i % 40) as i32]).unwrap();
        branches.push(b);
    }
    let mut tokens = 0usize;
    for b in branches.iter_mut() {
        tokens += b.generate(max_new, None, |_| true).unwrap().steps;
    }
    let wall_ns = t0.elapsed().as_nanos() as u64;
    let parity = branches.iter().map(parity_diff).fold(0.0f32, f32::max);
    let res = ModeResult {
        wall_ns,
        tokens,
        pages_used: kv.occupancy().0,
        slab_pages: kv.pages_resident(),
    };
    (res, parity)
}

/// The baseline: `fanout` independent sessions each ingesting the full
/// prompt (+ the same divergence token) before decoding.
fn run_independent(p: &[i32], fanout: usize, max_new: usize) -> ModeResult {
    let kv = pool(4096);
    let m = model();
    let t0 = Instant::now();
    let mut sessions: Vec<DecodeSession> = Vec::with_capacity(fanout);
    for i in 0..fanout {
        let mut s =
            DecodeSession::new(Arc::clone(&kv), Arc::clone(&m), policy(max_new), 1 + i as u64)
                .unwrap();
        s.prefill(p).unwrap();
        s.prefill(&[vocab::WORD0 + (i % 40) as i32]).unwrap();
        sessions.push(s);
    }
    let mut tokens = 0usize;
    for s in sessions.iter_mut() {
        tokens += s.generate(max_new, None, |_| true).unwrap().steps;
    }
    ModeResult {
        wall_ns: t0.elapsed().as_nanos() as u64,
        tokens,
        pages_used: kv.occupancy().0,
        slab_pages: kv.pages_resident(),
    }
}

/// Stats of one prefix-reuse serving run (exact or radix discipline).
struct ReuseResult {
    /// Prompt tokens actually projected + appended (the cost the radix
    /// tree exists to cut).
    ingest_tokens: usize,
    /// Groups served by forking a page-aligned partial prefix.
    partial_hits: usize,
    /// Branch token streams, in submission order.
    streams: Vec<Vec<i32>>,
    pages_used: usize,
    wall_ns: u64,
    /// Worst dense-oracle deviation across all branches.
    parity: f32,
}

/// A family of `count` prompts: a shared page-aligned prefix covering
/// `shared` tokens, then a distinct seeded suffix per prompt.
fn prompt_family(count: usize, total_len: usize, shared: usize) -> Vec<Vec<i32>> {
    let stem = prompt(shared);
    (0..count)
        .map(|i| {
            let mut p = stem.clone();
            let mut r = Rng::new(1000 + i as u64);
            p.extend((shared..total_len).map(|_| vocab::WORD0 + r.below(64) as i32));
            p
        })
        .collect()
}

/// Serve each prompt at `fanout` through a session-level prefix cache.
/// `radix = false` models exact mode (only byte-identical prompts reuse
/// a holder); `radix = true` additionally forks the longest page-aligned
/// common prefix of any cached holder and ingests just the suffix —
/// the same routing the coordinator's dispatcher performs, minus the
/// threading.
fn run_prefix_reuse(
    prompts: &[Vec<i32>],
    fanout: usize,
    max_new: usize,
    radix: bool,
) -> ReuseResult {
    let kv = pool(8192);
    let m = model();
    let index = RadixIndex::new(BLOCK);
    // holder sessions with their full prompts; RadixIndex keys are
    // indices into this vec
    let mut holders: Vec<(Vec<i32>, DecodeSession)> = Vec::new();
    let mut next_seq = 1u64;
    let mut seq = move || {
        next_seq += 1;
        next_seq
    };
    let mut ingest_tokens = 0usize;
    let mut partial_hits = 0usize;
    let mut streams = Vec::new();
    let mut branches = Vec::new();
    let t0 = Instant::now();
    for p in prompts {
        let holder_idx = match holders.iter().position(|(held, _)| held == p) {
            Some(i) => i, // exact hit: both modes fork the parked holder
            None => {
                let (mut sess, covered) = if radix {
                    match index.lookup(p) {
                        Some(mtc) if mtc.covered > 0 => {
                            partial_hits += 1;
                            let src = &holders[mtc.key as usize].1;
                            (
                                src.fork_prefix(seq(), mtc.covered, p[mtc.covered - 1])
                                    .expect("prefix fork"),
                                mtc.covered,
                            )
                        }
                        _ => (
                            DecodeSession::new(
                                Arc::clone(&kv),
                                Arc::clone(&m),
                                policy(max_new),
                                seq(),
                            )
                            .expect("session"),
                            0,
                        ),
                    }
                } else {
                    (
                        DecodeSession::new(
                            Arc::clone(&kv),
                            Arc::clone(&m),
                            policy(max_new),
                            seq(),
                        )
                        .expect("session"),
                        0,
                    )
                };
                sess.extend_prompt(&p[covered..]).expect("suffix ingest");
                ingest_tokens += p.len() - covered;
                index.insert(holders.len() as u64, p);
                holders.push((p.clone(), sess));
                holders.len() - 1
            }
        };
        for b in 0..fanout {
            let mut br = holders[holder_idx].1.fork(seq()).expect("branch fork");
            br.prefill(&[vocab::WORD0 + (b % 40) as i32]).expect("divergence token");
            streams.push(br.generate(max_new, None, |_| true).expect("decode").tokens);
            branches.push(br);
        }
    }
    let wall_ns = t0.elapsed().as_nanos() as u64;
    let parity = branches.iter().map(parity_diff).fold(0.0f32, f32::max);
    ReuseResult {
        ingest_tokens,
        partial_hits,
        streams,
        pages_used: kv.occupancy().0,
        wall_ns,
        parity,
    }
}

fn reuse_json(r: &ReuseResult) -> Json {
    Json::obj(vec![
        ("ingest_tokens", Json::Num(r.ingest_tokens as f64)),
        ("partial_hits", Json::Num(r.partial_hits as f64)),
        ("pages_used", Json::Num(r.pages_used as f64)),
        ("wall_ns", Json::Num(r.wall_ns as f64)),
        ("parity_max_diff", Json::Num(r.parity as f64)),
    ])
}

fn mode_json(r: &ModeResult) -> Json {
    Json::obj(vec![
        ("wall_ns", Json::Num(r.wall_ns as f64)),
        ("tokens", Json::Num(r.tokens as f64)),
        ("ns_per_token", Json::Num(r.ns_per_token())),
        ("pages_used", Json::Num(r.pages_used as f64)),
        ("slab_pages_resident", Json::Num(r.slab_pages as f64)),
    ])
}

fn main() {
    let args = Args::parse(std::env::args().skip(1), false);
    let quick = args.flag("quick");
    let threads = args.init_thread_pool();
    let fanout = args.usize_or("fanout", 4).max(1);
    let prompt_len = args.usize_or("prompt-len", if quick { 1024 } else { 4096 });
    let max_new = args.usize_or("max-new", if quick { 16 } else { 64 });
    let (h, hk, dh) = GEO;

    let p = prompt(prompt_len);
    let (forked, parity) = run_forked(&p, fanout, max_new);
    let independent = run_independent(&p, fanout, max_new);

    // --- token-granular prefix reuse: exact vs radix ---------------------
    // 8 prompts sharing a 75% page-aligned token prefix, each served at
    // fanout 2 (the acceptance workload for the radix prefix cache)
    let family_n = 8usize;
    let reuse_fanout = 2usize;
    let reuse_max_new = if quick { 12 } else { 24 };
    let shared = (prompt_len * 3 / 4) / BLOCK * BLOCK; // page-aligned 75%
    let family = prompt_family(family_n, prompt_len, shared);
    let exact = run_prefix_reuse(&family, reuse_fanout, reuse_max_new, false);
    let radix = run_prefix_reuse(&family, reuse_fanout, reuse_max_new, true);
    let ingest_savings = exact.ingest_tokens as f64 / radix.ingest_tokens.max(1) as f64;

    let page_savings = independent.pages_used as f64 / forked.pages_used.max(1) as f64;
    let throughput_ratio = independent.wall_ns as f64 / forked.wall_ns.max(1) as f64;
    println!(
        "fanout={fanout} prompt={prompt_len} max_new={max_new} ({threads} threads)\n\
         forked:      {:>10.0} ns/token | {:>4} pages used | {:>4} slabs resident\n\
         independent: {:>10.0} ns/token | {:>4} pages used | {:>4} slabs resident\n\
         -> page savings {page_savings:.2}x (target >= 2x) | aggregate throughput \
         {throughput_ratio:.2}x (target >= 1.5x)\n\
         -> forked decode parity vs dense oracle: max |diff| = {parity:.2e} (gate 1e-5)",
        forked.ns_per_token(),
        forked.pages_used,
        forked.slab_pages,
        independent.ns_per_token(),
        independent.pages_used,
        independent.slab_pages,
    );
    assert!(parity < 1e-5, "forked decode parity broke the 1e-5 oracle gate: {parity}");
    // page accounting is deterministic (unlike wall time), so the
    // savings target is a hard gate even on noisy runners; the 2x
    // acceptance number is defined at fanout >= 4
    assert!(
        fanout < 4 || page_savings >= 2.0,
        "fanout={fanout} page savings {page_savings:.2}x below the 2x acceptance target"
    );

    println!(
        "prefix reuse: {family_n} prompts, {shared}/{prompt_len} shared tokens, fanout {reuse_fanout}\n\
         exact: {:>6} ingest tokens | {:>4} pages | radix: {:>6} ingest tokens \
         ({} partial hits) | {:>4} pages\n\
         -> ingest savings {ingest_savings:.2}x (target >= 1.5x) | radix parity max |diff| = {:.2e}",
        exact.ingest_tokens,
        exact.pages_used,
        radix.ingest_tokens,
        radix.partial_hits,
        radix.pages_used,
        radix.parity,
    );
    // token accounting is deterministic: all three reuse gates are hard
    assert_eq!(
        exact.streams, radix.streams,
        "radix prefix reuse changed a decode stream vs exact-mode full ingest"
    );
    assert!(radix.parity < 1e-5, "radix-reused decode parity broke 1e-5: {}", radix.parity);
    assert!(radix.partial_hits > 0, "the 75%-shared family must produce partial prefix hits");
    assert!(
        ingest_savings >= 1.5,
        "radix ingest savings {ingest_savings:.2}x below the 1.5x acceptance target"
    );

    let out = Json::obj(vec![
        ("bench", Json::Str("bench_fanout".into())),
        ("threads", Json::Num(threads as f64)),
        ("quick", Json::Bool(quick)),
        ("fanout", Json::Num(fanout as f64)),
        ("prompt_len", Json::Num(prompt_len as f64)),
        ("max_new", Json::Num(max_new as f64)),
        (
            "geometry",
            Json::obj(vec![
                ("h", Json::Num(h as f64)),
                ("hk", Json::Num(hk as f64)),
                ("dh", Json::Num(dh as f64)),
                ("block", Json::Num(BLOCK as f64)),
            ]),
        ),
        (
            "results",
            Json::obj(vec![
                ("forked", mode_json(&forked)),
                ("independent", mode_json(&independent)),
                ("page_savings", Json::Num(page_savings)),
                ("throughput_ratio", Json::Num(throughput_ratio)),
                ("parity_max_diff", Json::Num(parity as f64)),
            ]),
        ),
        (
            "prefix_reuse",
            Json::obj(vec![
                ("prompts", Json::Num(family_n as f64)),
                ("shared_tokens", Json::Num(shared as f64)),
                ("prompt_len", Json::Num(prompt_len as f64)),
                ("fanout", Json::Num(reuse_fanout as f64)),
                ("max_new", Json::Num(reuse_max_new as f64)),
                ("exact", reuse_json(&exact)),
                ("radix", reuse_json(&radix)),
                ("ingest_savings", Json::Num(ingest_savings)),
                ("streams_identical", Json::Bool(exact.streams == radix.streams)),
            ]),
        ),
    ]);
    let path = "BENCH_fanout.json";
    match std::fs::write(path, format!("{out}")) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
