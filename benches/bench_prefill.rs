//! Figure 1, measured half: end-to-end prefill latency of every compiled
//! method at every bucket on this testbed (XLA-CPU), printed alongside
//! the analytic H20 projection so the *shape* can be compared to the
//! paper (who wins, by what factor, where the crossovers sit).
//!
//!   cargo bench --bench bench_prefill            # all buckets
//!   cargo bench --bench bench_prefill -- --quick # smaller sample counts

use std::path::Path;

use stem::runtime::{Engine, ScalarValue};
use stem::sim::{estimate_core_prefill_ns, project_figure1, Geometry, MethodCost};
use stem::util::bench::{black_box, Bencher};
use stem::util::cli::Args;

fn scalars_for(engine: &Engine, kind: &str, n: usize) -> Vec<ScalarValue> {
    let d = engine.manifest().defaults_for(n).expect("defaults");
    match kind {
        "prefill_dense" => vec![],
        "prefill_stem" => vec![
            ScalarValue::F32(d.k_start as f32),
            ScalarValue::F32(d.mu as f32),
            ScalarValue::F32(d.beta as f32),
        ],
        "prefill_streaming" => {
            vec![ScalarValue::I32(d.sink_blocks as i32), ScalarValue::I32(d.local_blocks as i32)]
        }
        "prefill_xattn" => vec![ScalarValue::F32(d.xattn_tau as f32)],
        "prefill_minference" => {
            vec![ScalarValue::I32(d.minf_vertical as i32), ScalarValue::I32(d.minf_slash as i32)]
        }
        "prefill_flexprefill" => {
            vec![ScalarValue::F32(d.flex_gamma as f32), ScalarValue::F32(d.flex_entropy as f32)]
        }
        other => panic!("unknown kind {other}"),
    }
}

fn main() {
    let args = Args::parse(std::env::args().skip(1), false);
    let quick = args.flag("quick");
    let threads = args.init_thread_pool();
    let artifacts = stem::artifacts_dir();
    let engine = Engine::new(&artifacts).expect("run `make artifacts` first");
    let man = engine.manifest().clone();
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };

    let kinds = [
        "prefill_dense",
        "prefill_streaming",
        "prefill_minference",
        "prefill_flexprefill",
        "prefill_xattn",
        "prefill_stem",
    ];
    let buckets: Vec<usize> = {
        let mut b: Vec<usize> =
            man.modules.iter().filter(|m| !m.is_diag()).map(|m| m.n_ctx).collect();
        b.sort();
        b.dedup();
        b
    };

    println!("== Figure 1 (measured, XLA-CPU, this model) ==");
    let mut dense_med = std::collections::HashMap::new();
    let mut stem_med = std::collections::HashMap::new();
    for &n in &buckets {
        // mixed-token prompt: realistic id entropy (not all-PAD)
        let mut rng = stem::util::rng::Rng::new(7);
        let ids: Vec<i32> =
            (0..n).map(|_| 16 + (rng.below(64) as i32)).collect();
        for kind in kinds {
            if man.module(kind, n).is_err() {
                continue;
            }
            let scalars = scalars_for(&engine, kind, n);
            engine.ensure_module(kind, n).expect("compile");
            let st = bencher.run(&format!("{kind}@{n}"), || {
                let o = engine.prefill("base", kind, n, &ids, &scalars).expect("exec");
                black_box(o.budget_fraction);
            });
            st.print();
            if kind == "prefill_dense" {
                dense_med.insert(n, st.median_ns);
            }
            if kind == "prefill_stem" {
                stem_med.insert(n, st.median_ns);
            }
        }
    }
    println!("\nspeedup dense/stem per bucket (paper at 128K: 3.7x):");
    for &n in &buckets {
        if let (Some(d), Some(s)) = (dense_med.get(&n), stem_med.get(&n)) {
            println!("  n={n}: {:.2}x", d / s);
        }
    }

    println!("\n== Figure 1 (analytic H20 projection, Llama-3.1-8B geometry) ==");
    for p in project_figure1(&[16384, 32768, 65536, 131072]) {
        println!(
            "  {:<12} {:>6}K  kernel {:>7.0} ms  total {:>7.0} ms  budget {:>5.1}%",
            p.method,
            p.n_ctx / 1024,
            p.kernel_ms,
            p.total_ms,
            100.0 * p.budget_fraction
        );
    }

    // pure-rust reference core: calibrated wall-clock projection of the
    // same comparison (the admission-control work estimator)
    println!("\n== pure-rust core projection (calibrated constants, {threads} threads) ==");
    let g = Geometry { n_layers: 1, n_heads: 8, d_head: 32, d_model: 256, d_ff: 1024, block: 64 };
    for n in [2048usize, 4096, 8192] {
        let nblk = (n / g.block) as f64;
        let dense = estimate_core_prefill_ns(&g, n, MethodCost::Dense, threads);
        let stem = estimate_core_prefill_ns(
            &g,
            n,
            MethodCost::Stem { k_start_blocks: 0.2 * nblk, mu: 0.7 },
            threads,
        );
        println!(
            "  n={n:<6} dense {:>9.2} ms  stem {:>9.2} ms  projected speedup {:.2}x",
            dense / 1e6,
            stem / 1e6,
            dense / stem
        );
    }
    let _ = Path::new("");
}
