//! Micro-benchmarks of the pure-rust sparse core (pooling, metric,
//! selection, attention) across sizes — the perf-pass iteration target
//! for the L3 reference path (EXPERIMENTS.md §Perf).

use stem::sparse::schedule::TpdConfig;
use stem::sparse::{
    antidiag_scores, block_sparse_attention, dense_attention, oam_scores, select_stem, Tensor,
};
use stem::util::bench::{black_box, Bencher};
use stem::util::rng::Rng;

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    let (h, hk, dh, block, stride) = (8usize, 4usize, 32usize, 64usize, 16usize);

    for n in [512usize, 1024, 2048] {
        let mut rng = Rng::new(3);
        let q = Tensor::randn(&[h, n, dh], &mut rng);
        let k = Tensor::randn(&[hk, n, dh], &mut rng);
        let v = Tensor::randn(&[hk, n, dh], &mut rng);
        let nblk = (n / block) as f64;
        let cfg = TpdConfig { k_start: 0.2 * nblk, mu: 0.7, ..Default::default() };

        bencher.run(&format!("antidiag_scores n={n}"), || {
            black_box(antidiag_scores(&q, &k, block, stride));
        }).print();
        bencher.run(&format!("oam_scores n={n}"), || {
            black_box(oam_scores(&q, &k, &v, block, stride, 0.2));
        }).print();
        bencher.run(&format!("select_stem n={n}"), || {
            black_box(select_stem(&q, &k, &v, block, stride, &cfg, 0.2));
        }).print();
        let sel = select_stem(&q, &k, &v, block, stride, &cfg, 0.2);
        let s_sparse = bencher.run(&format!("block_sparse_attention n={n}"), || {
            black_box(block_sparse_attention(&q, &k, &v, &sel, block));
        });
        s_sparse.print();
        let s_dense = bencher.run(&format!("dense_attention n={n}"), || {
            black_box(dense_attention(&q, &k, &v));
        });
        s_dense.print();
        println!(
            "  -> rust-core dense/sparse ratio at n={n}: {:.2}x (budget {:.1}%)\n",
            s_dense.median_ns / s_sparse.median_ns,
            100.0 * sel.budget_fraction()
        );
    }
}
