//! Micro-benchmarks of the pure-rust sparse core (pooling, metric,
//! selection, attention) across sizes — the perf-pass iteration target
//! for the L3 reference path (EXPERIMENTS.md §Perf).
//!
//! Measures the PR-1 flat-CSR parallel pipeline against the retained
//! seed-shaped scalar path (`select_stem_reference`,
//! `block_sparse_attention_reference`) and writes machine-readable
//! results to `BENCH_sparse_core.json` so future PRs have a perf
//! trajectory.
//!
//!   cargo bench --bench bench_sparse_core                 # full sizes
//!   cargo bench --bench bench_sparse_core -- --quick      # small samples
//!   cargo bench --bench bench_sparse_core -- --threads 1  # serial core

use stem::sparse::schedule::TpdConfig;
use stem::sparse::simd::{arm_label, SimdArm};
use stem::sparse::{
    antidiag_scores, block_sparse_attention, block_sparse_attention_reference,
    block_sparse_attention_with, dense_attention, dense_attention_with, oam_scores,
    oam_scores_with, select_stem, select_stem_reference, Tensor,
};
use stem::util::bench::{black_box, Bencher, Stats};
use stem::util::cli::Args;
use stem::util::json::Json;
use stem::util::rng::Rng;

struct Row {
    method: String,
    n: usize,
    median_ns: f64,
    /// vs the retained seed scalar path at the same (method, n); 0 = n/a
    speedup_vs_seed: f64,
}

fn row(st: &Stats, n: usize, speedup: f64) -> Row {
    Row { method: st.name.clone(), n, median_ns: st.median_ns, speedup_vs_seed: speedup }
}

fn main() {
    let args = Args::parse(std::env::args().skip(1), false);
    let quick = args.flag("quick");
    let threads = args.init_thread_pool();
    let bencher = if quick { Bencher::quick() } else { Bencher::default() };
    let (h, hk, dh, block, stride) = (8usize, 4usize, 32usize, 64usize, 16usize);
    let mut rows: Vec<Row> = vec![];

    for n in [512usize, 1024, 2048, 4096] {
        let mut rng = Rng::new(3);
        let q = Tensor::randn(&[h, n, dh], &mut rng);
        let k = Tensor::randn(&[hk, n, dh], &mut rng);
        let v = Tensor::randn(&[hk, n, dh], &mut rng);
        let nblk = (n / block) as f64;
        let cfg = TpdConfig { k_start: 0.2 * nblk, mu: 0.7, ..Default::default() };

        let s = bencher.run(&format!("antidiag_scores n={n}"), || {
            black_box(antidiag_scores(&q, &k, block, stride));
        });
        s.print();
        rows.push(row(&s, n, 0.0));
        let s = bencher.run(&format!("oam_scores n={n}"), || {
            black_box(oam_scores(&q, &k, &v, block, stride, 0.2));
        });
        s.print();
        rows.push(row(&s, n, 0.0));

        let s_sel_ref = bencher.run(&format!("select_stem_reference n={n}"), || {
            black_box(select_stem_reference(&q, &k, &v, block, stride, &cfg, 0.2));
        });
        s_sel_ref.print();
        rows.push(row(&s_sel_ref, n, 1.0));
        let s_sel = bencher.run(&format!("select_stem n={n}"), || {
            black_box(select_stem(&q, &k, &v, block, stride, &cfg, 0.2));
        });
        s_sel.print();
        rows.push(row(&s_sel, n, s_sel_ref.median_ns / s_sel.median_ns));

        let sel = select_stem(&q, &k, &v, block, stride, &cfg, 0.2);
        let s_attn_ref = bencher.run(&format!("block_sparse_attention_reference n={n}"), || {
            black_box(block_sparse_attention_reference(&q, &k, &v, &sel, block));
        });
        s_attn_ref.print();
        rows.push(row(&s_attn_ref, n, 1.0));
        let s_attn = bencher.run(&format!("block_sparse_attention n={n}"), || {
            black_box(block_sparse_attention(&q, &k, &v, &sel, block));
        });
        s_attn.print();
        rows.push(row(&s_attn, n, s_attn_ref.median_ns / s_attn.median_ns));

        // acceptance figure: selection + execution, new pipeline vs seed
        let combined_seed = s_sel_ref.median_ns + s_attn_ref.median_ns;
        let combined_new = s_sel.median_ns + s_attn.median_ns;
        rows.push(Row {
            method: "select_stem+block_sparse_attention".into(),
            n,
            median_ns: combined_new,
            speedup_vs_seed: combined_seed / combined_new,
        });
        println!(
            "  -> select+attention speedup vs seed scalar path at n={n}: {:.2}x ({threads} threads)",
            combined_seed / combined_new
        );

        // dense reference is O(N²·dh) scalar work per head: cap the size
        if n <= 2048 {
            let s_dense = bencher.run(&format!("dense_attention n={n}"), || {
                black_box(dense_attention(&q, &k, &v));
            });
            s_dense.print();
            rows.push(row(&s_dense, n, 0.0));
            println!(
                "  -> rust-core dense/sparse ratio at n={n}: {:.2}x (budget {:.1}%)\n",
                s_dense.median_ns / s_attn.median_ns,
                100.0 * sel.budget_fraction()
            );
        } else {
            println!(
                "  -> budget {:.1}% at n={n} (dense reference skipped above 2048)\n",
                100.0 * sel.budget_fraction()
            );
        }
    }

    // --- simd: explicit-arm A/B over the vectorized prefill kernels -------
    // fixed inputs and one shared selection per stage, so the two arms
    // differ only in lane math; the CI bench-smoke gate reads these rows
    // and requires speedup >= 1.0 (target: >= 2x on the fused kernel at
    // n=4096, single thread)
    let simd_n = if quick { 512usize } else { 4096 };
    // (stage, n, scalar_ns, wide_ns)
    let mut simd_rows: Vec<(&'static str, usize, f64, f64)> = vec![];
    {
        let mut rng = Rng::new(5);
        let q = Tensor::randn(&[h, simd_n, dh], &mut rng);
        let k = Tensor::randn(&[hk, simd_n, dh], &mut rng);
        let v = Tensor::randn(&[hk, simd_n, dh], &mut rng);
        let nblk = (simd_n / block) as f64;
        let cfg = TpdConfig { k_start: 0.2 * nblk, mu: 0.7, ..Default::default() };
        let sel = select_stem(&q, &k, &v, block, stride, &cfg, 0.2);

        let sc = bencher.run(&format!("simd=scalar block_sparse_attention n={simd_n}"), || {
            black_box(block_sparse_attention_with(SimdArm::Scalar, &q, &k, &v, &sel, block));
        });
        sc.print();
        let wi = bencher.run(&format!("simd=wide block_sparse_attention n={simd_n}"), || {
            black_box(block_sparse_attention_with(SimdArm::Wide, &q, &k, &v, &sel, block));
        });
        wi.print();
        simd_rows.push(("block_sparse_attention", simd_n, sc.median_ns, wi.median_ns));

        let sc = bencher.run(&format!("simd=scalar oam_scores n={simd_n}"), || {
            black_box(oam_scores_with(SimdArm::Scalar, &q, &k, &v, block, stride, 0.2));
        });
        sc.print();
        let wi = bencher.run(&format!("simd=wide oam_scores n={simd_n}"), || {
            black_box(oam_scores_with(SimdArm::Wide, &q, &k, &v, block, stride, 0.2));
        });
        wi.print();
        simd_rows.push(("oam_scores", simd_n, sc.median_ns, wi.median_ns));

        // dense is O(N²·dh): cap its size so the A/B stays cheap
        let dn = if quick { 256usize } else { 1024 };
        let mut rng = Rng::new(6);
        let qd = Tensor::randn(&[h, dn, dh], &mut rng);
        let kd = Tensor::randn(&[hk, dn, dh], &mut rng);
        let vd = Tensor::randn(&[hk, dn, dh], &mut rng);
        let sc = bencher.run(&format!("simd=scalar dense_attention n={dn}"), || {
            black_box(dense_attention_with(SimdArm::Scalar, &qd, &kd, &vd));
        });
        sc.print();
        let wi = bencher.run(&format!("simd=wide dense_attention n={dn}"), || {
            black_box(dense_attention_with(SimdArm::Wide, &qd, &kd, &vd));
        });
        wi.print();
        simd_rows.push(("dense_attention", dn, sc.median_ns, wi.median_ns));
    }
    for &(stage, n, sc, wi) in &simd_rows {
        println!("  -> simd {stage} n={n}: {:.2}x ({})", sc / wi, arm_label(SimdArm::Wide));
    }

    let out = Json::obj(vec![
        ("bench", Json::Str("bench_sparse_core".into())),
        ("threads", Json::Num(threads as f64)),
        ("quick", Json::Bool(quick)),
        (
            "geometry",
            Json::obj(vec![
                ("h", Json::Num(h as f64)),
                ("hk", Json::Num(hk as f64)),
                ("dh", Json::Num(dh as f64)),
                ("block", Json::Num(block as f64)),
                ("stride", Json::Num(stride as f64)),
            ]),
        ),
        (
            "results",
            Json::Arr(
                rows.iter()
                    .map(|r| {
                        Json::obj(vec![
                            ("method", Json::Str(r.method.clone())),
                            ("n", Json::Num(r.n as f64)),
                            ("ns_per_iter", Json::Num(r.median_ns)),
                            ("speedup_vs_seed", Json::Num(r.speedup_vs_seed)),
                        ])
                    })
                    .collect(),
            ),
        ),
        (
            "simd",
            Json::obj(vec![
                ("dispatch", Json::Str(arm_label(SimdArm::Wide).into())),
                ("target_speedup", Json::Num(2.0)),
                (
                    "rows",
                    Json::Arr(
                        simd_rows
                            .iter()
                            .map(|&(stage, n, sc, wi)| {
                                Json::obj(vec![
                                    ("stage", Json::Str(stage.into())),
                                    ("n", Json::Num(n as f64)),
                                    ("scalar_ns", Json::Num(sc)),
                                    ("wide_ns", Json::Num(wi)),
                                    ("speedup", Json::Num(sc / wi)),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
        ),
    ]);
    let path = "BENCH_sparse_core.json";
    match std::fs::write(path, format!("{out}")) {
        Ok(()) => println!("wrote {path} ({} result rows)", rows.len()),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }
}
