"""Selection-method invariants: every method must emit a valid selection
for the uniform kernel interface (unique causal ids, counts in range,
forced blocks present) and respect its budget semantics."""

import numpy as np
import pytest
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st, HealthCheck

from compile import methods
from compile.kernels import ref

SET = dict(deadline=None, max_examples=8,
           suppress_health_check=[HealthCheck.too_slow])


def qkv(seed, h=4, hk=2, n=512, dh=16):
    rng = np.random.default_rng(seed)
    return (jnp.asarray(rng.normal(size=(h, n, dh)).astype(np.float32)),
            jnp.asarray(rng.normal(size=(hk, n, dh)).astype(np.float32)),
            jnp.asarray(rng.normal(size=(hk, n, dh)).astype(np.float32)))


def check_valid(idx, cnt, nblk):
    idx, cnt = np.asarray(idx), np.asarray(cnt)
    h = idx.shape[0]
    assert idx.shape == (h, nblk, nblk)
    assert cnt.shape == (h, nblk)
    assert (cnt >= 1).all()
    for hh in range(h):
        for i in range(nblk):
            c = cnt[hh, i]
            assert c <= i + 1, f"count {c} exceeds causal width {i+1}"
            sel = idx[hh, i, :c]
            assert (sel <= i).all(), "non-causal block selected"
            assert len(set(sel.tolist())) == c, "duplicate block ids"


def selected_sets(idx, cnt):
    idx, cnt = np.asarray(idx), np.asarray(cnt)
    return [[set(idx[h, i, :cnt[h, i]].tolist())
             for i in range(idx.shape[1])] for h in range(idx.shape[0])]


@settings(**SET)
@given(seed=st.integers(0, 1000), ks=st.sampled_from([2.0, 3.0, 5.0]),
       mu=st.sampled_from([0.5, 0.7, 1.0]),
       beta=st.sampled_from([0.0, 0.2]))
def test_stem_valid_and_forced(seed, ks, mu, beta):
    q, k, v = qkv(seed)
    nblk = 8
    idx, cnt, bud = methods.select_stem(q, k, v, 64, ks, mu, beta)
    check_valid(idx, cnt, nblk)
    sets = selected_sets(idx, cnt)
    for h in range(4):
        for i in range(nblk):
            assert 0 in sets[h][i], "sink block must always survive"
            assert i in sets[h][i], "diagonal block must always survive"
    assert 0.0 < float(bud) <= 1.0


def test_stem_mu_one_is_uniform_budget():
    q, k, v = qkv(0)
    _, cnt1, _ = methods.select_stem(q, k, v, 64, 4.0, 1.0, 0.0)
    cnt1 = np.asarray(cnt1)
    width = np.arange(8) + 1
    expect = np.minimum(np.maximum(4, 3), width)  # k_start clamped
    assert (cnt1[0] == np.minimum(4, width).clip(min=np.minimum(3, width))).all()


def test_stem_budget_decreases_with_mu():
    q, k, v = qkv(1, n=2048)
    _, _, b_low = methods.select_stem(q, k, v, 64, 8.0, 0.5, 0.2)
    _, _, b_hi = methods.select_stem(q, k, v, 64, 8.0, 1.0, 0.2)
    assert float(b_low) < float(b_hi)


def test_stem_ref_agrees_with_kernel_selection():
    q, k, v = qkv(2)
    i1, c1, b1 = methods.select_stem(q, k, v, 64, 3.0, 0.7, 0.2)
    i2, c2, b2 = methods.select_stem_ref(q, k, v, 64, 3.0, 0.7, 0.2)
    assert (np.asarray(c1) == np.asarray(c2)).all()
    assert selected_sets(i1, c1) == selected_sets(i2, c2)


def test_streaming_pattern():
    q, k, v = qkv(3)
    idx, cnt, bud = methods.select_streaming(q, 64, 1, 2)
    check_valid(idx, cnt, 8)
    sets = selected_sets(idx, cnt)
    for i in range(8):
        want = ({0} | {j for j in range(max(0, i - 1), i + 1)})
        assert sets[0][i] == want, f"row {i}: {sets[0][i]} != {want}"


@settings(**SET)
@given(seed=st.integers(0, 1000), tau=st.sampled_from([0.5, 0.9, 0.99]))
def test_xattn_valid_and_tau_monotone(seed, tau):
    q, k, v = qkv(seed)
    idx, cnt, bud = methods.select_xattn(q, k, v, 64, tau)
    check_valid(idx, cnt, 8)


def test_xattn_budget_grows_with_tau():
    q, k, v = qkv(5, n=1024)
    _, _, b1 = methods.select_xattn(q, k, v, 64, 0.5)
    _, _, b2 = methods.select_xattn(q, k, v, 64, 0.99)
    assert float(b1) <= float(b2)


def test_minference_vertical_and_slash():
    q, k, v = qkv(6, n=1024)
    nblk = 16
    idx, cnt, bud = methods.select_minference(q, k, v, 64, 3, 2)
    check_valid(idx, cnt, nblk)
    sets = selected_sets(idx, cnt)
    # slash: diagonal and previous band present everywhere
    for i in range(nblk):
        assert i in sets[0][i]
        if i >= 1:
            assert (i - 1) in sets[0][i]


def test_flexprefill_mixes_patterns():
    q, k, v = qkv(7, n=1024)
    idx, cnt, bud = methods.select_flexprefill(q, k, v, 64, 0.9, 0.35)
    check_valid(idx, cnt, 16)


def test_segment_dense_outside():
    q, k, v = qkv(8, n=1024)
    nblk = 16
    idx, cnt, _ = methods.select_segment(q, k, v, 64, 4, 8, 2, 0.0)
    check_valid(idx, cnt, nblk)
    cnt = np.asarray(cnt)
    for i in range(nblk):
        if 4 <= i < 8:
            assert cnt[0, i] == min(2, i + 1)
        else:
            assert cnt[0, i] == i + 1, f"row {i} must be dense"


def test_segment_ratio_mode():
    q, k, v = qkv(9, n=1024)
    idx, cnt, _ = methods.select_segment(q, k, v, 64, 0, 16, 0, 0.5)
    cnt = np.asarray(cnt)
    for i in range(16):
        assert cnt[0, i] == int(np.ceil(0.5 * (i + 1)))


def test_sparse_output_closer_with_larger_budget():
    """Sanity on the whole pipeline: more budget => lower error vs dense."""
    q, k, v = qkv(10, n=1024)
    dense_o = ref.dense_attention(q, k, v)
    errs = []
    for ks in (3.0, 6.0, 12.0):
        idx, cnt, _ = methods.select_stem(q, k, v, 64, ks, 0.7, 0.2)
        o = ref.block_sparse_attention(q, k, v, idx, cnt, 64)
        errs.append(float(jnp.mean((o - dense_o) ** 2)))
    assert errs[0] >= errs[1] >= errs[2]
