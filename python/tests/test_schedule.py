"""TPD schedule and cost-model algebra (paper Eq. 2-4, §3.3)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import schedule as S

SET = dict(deadline=None, max_examples=50)


def test_k_at_endpoints():
    # k(1) ~ k_start, k(N) ~ mu * k_start (floor effects aside)
    n, ks, mu = 1000, 100.0, 0.7
    k = S.k_schedule(n, S.TPDConfig(k_start=ks, mu=mu))
    assert k[0] <= ks and k[0] >= ks - 1 - ks * (1 - mu) / n
    assert abs(k[-1] - mu * ks) <= 1.0


@settings(**SET)
@given(ks=st.floats(4, 64), mu=st.floats(0.3, 1.0),
       n=st.integers(64, 4096))
def test_schedule_monotone_nonincreasing(ks, mu, n):
    k = S.k_schedule(n, S.TPDConfig(k_start=ks, mu=mu))
    assert (np.diff(k) <= 0).all()


@settings(**SET)
@given(ks=st.floats(4, 64), mu=st.floats(0.3, 0.999),
       n=st.integers(128, 8192))
def test_decay_cheaper_than_uniform(ks, mu, n):
    if ks >= n:
        return
    assert S.cost_decay(n, ks, mu) < S.cost_uniform(n, ks)


def test_decay_equals_uniform_at_mu_one():
    assert S.cost_decay(2048, 32.0, 1.0) == pytest.approx(
        S.cost_uniform(2048, 32.0))


@settings(**SET)
@given(ks=st.floats(8, 64), mu=st.floats(0.4, 1.0))
def test_budget_matching_rule(ks, mu):
    """C_uni(k_uni) ~= C_decay(k_start, mu) for N >> k_start (§3.3)."""
    n = 1 << 16
    k_uni = S.k_uniform_matched(ks, mu)
    c_uni = S.cost_uniform(n, k_uni)
    c_dec = S.cost_decay(n, ks, mu)
    assert abs(c_uni - c_dec) / c_dec < 0.02


def test_eq4_matches_discrete_sum():
    """Closed-form C_decay tracks the literal sum of clamped k(i)."""
    n, ks, mu = 4096, 64.0, 0.7
    k = S.k_schedule(n, S.TPDConfig(k_start=ks, mu=mu))
    discrete = float(np.minimum(k, np.arange(n) + 1).sum())
    closed = S.cost_decay(n, ks, mu)
    assert abs(discrete - closed) / closed < 0.02


@settings(**SET)
@given(nblk=st.integers(4, 64), ks=st.floats(2, 32), mu=st.floats(0.3, 1.0))
def test_block_schedule_bounds(nblk, ks, mu):
    cfg = S.TPDConfig(k_start=ks, mu=mu, init_keep=1, local_keep=2,
                      min_total=3)
    k = S.block_budget_schedule(nblk, cfg)
    width = np.arange(nblk) + 1
    assert (k >= 1).all()
    assert (k <= width).all()
    # floor respected wherever the causal width allows it
    ok = width >= cfg.min_total
    assert (k[ok] >= cfg.min_total).all()


def test_jnp_matches_numpy_schedule():
    import jax.numpy as jnp
    cfg = S.TPDConfig(k_start=8.0, mu=0.7)
    a = S.block_budget_schedule(32, cfg)
    b = np.asarray(S.block_budget_schedule_jnp(
        32, 8.0, 0.7, cfg.init_keep, cfg.local_keep, cfg.min_total))
    np.testing.assert_allclose(a, b)


def test_cost_stem_linear_in_n():
    """Eq. 8: doubling N with fixed k_avg roughly doubles C_stem's sparse
    term (metric term is the quadratic-but-tiny remainder)."""
    d, b, kavg = 256, 64, 512.0
    c1 = S.cost_stem(8192, d, b, kavg)
    c2 = S.cost_stem(16384, d, b, kavg)
    assert c2 / c1 < 2.4
