"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Shapes/dtypes are swept with hypothesis (the mandated property harness for
the kernel layer); each draw builds a random-but-valid selection and
asserts allclose at dtype-appropriate tolerance.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st, HealthCheck

from compile.kernels import block_sparse, dense, metric, ref

SETTINGS = dict(deadline=None, max_examples=12,
                suppress_health_check=[HealthCheck.too_slow])


def make_qkv(rng, h, hk, n, dh, dtype):
    q = rng.normal(size=(h, n, dh)).astype(dtype)
    k = rng.normal(size=(hk, n, dh)).astype(dtype)
    v = rng.normal(size=(hk, n, dh)).astype(dtype)
    return jnp.asarray(q), jnp.asarray(k), jnp.asarray(v)


def random_selection(rng, h, nblk, full_rows=False):
    """Valid (indices, counts): unique causal ids, >= 1 per row."""
    idx = np.zeros((h, nblk, nblk), np.int32)
    cnt = np.zeros((h, nblk), np.int32)
    for hh in range(h):
        for i in range(nblk):
            c = i + 1 if full_rows else int(rng.integers(1, i + 2))
            sel = rng.choice(i + 1, size=c, replace=False)
            idx[hh, i, :c] = sel
            cnt[hh, i] = c
    return jnp.asarray(idx), jnp.asarray(cnt)


@settings(**SETTINGS)
@given(
    h=st.sampled_from([1, 2, 4]),
    gqa=st.sampled_from([1, 2]),
    nblk=st.integers(2, 6),
    block=st.sampled_from([32, 64]),
    dh=st.sampled_from([16, 32]),
    dtype=st.sampled_from([np.float32, jnp.bfloat16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_block_sparse_vs_oracle(h, gqa, nblk, block, dh, dtype, seed):
    if h % gqa:
        gqa = 1
    hk = h // gqa
    rng = np.random.default_rng(seed)
    n = nblk * block
    q, k, v = make_qkv(rng, h, hk, n, dh, dtype)
    idx, cnt = random_selection(rng, h, nblk)
    got = block_sparse.block_sparse_attention(q, k, v, idx, cnt, block)
    want = ref.block_sparse_attention(q, k, v, idx, cnt, block)
    tol = 2e-5 if dtype == np.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


@settings(**SETTINGS)
@given(
    h=st.sampled_from([2, 4]),
    nblk=st.integers(2, 5),
    block=st.sampled_from([32, 64]),
    dh=st.sampled_from([16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_dense_kernel_vs_oracle(h, nblk, block, dh, seed):
    rng = np.random.default_rng(seed)
    n = nblk * block
    q, k, v = make_qkv(rng, h, h // 2, n, dh, np.float32)
    got = dense.dense_attention(q, k, v, block)
    want = ref.dense_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_full_selection_equals_dense():
    """Block-sparse with every causal block selected == dense attention."""
    rng = np.random.default_rng(0)
    h, hk, nblk, block, dh = 4, 2, 4, 64, 32
    n = nblk * block
    q, k, v = make_qkv(rng, h, hk, n, dh, np.float32)
    idx, cnt = random_selection(rng, h, nblk, full_rows=True)
    got = block_sparse.block_sparse_attention(q, k, v, idx, cnt, block)
    want = ref.dense_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


@settings(**SETTINGS)
@given(
    h=st.sampled_from([2, 4]),
    nblk=st.integers(2, 5),
    block=st.sampled_from([32, 64]),
    stride=st.sampled_from([8, 16]),
    beta=st.sampled_from([0.0, 0.2, 0.5]),
    seed=st.integers(0, 2**31 - 1),
)
def test_metric_kernel_vs_oracle(h, nblk, block, stride, beta, seed):
    rng = np.random.default_rng(seed)
    n = nblk * block
    q, k, v = make_qkv(rng, h, h // 2, n, 16, np.float32)
    got = metric.oam_block_scores(q, k, v, beta, block, stride)
    want = ref.oam_block_scores(q, k, v, block, beta, stride)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_metric_beta_zero_is_sam():
    """beta=0 must reduce OAM to the pure routing (SAM) score."""
    rng = np.random.default_rng(3)
    q, k, v = make_qkv(rng, 2, 1, 256, 16, np.float32)
    sam = ref.pool_antidiag_scores(q, k, 64)
    oam0 = ref.oam_block_scores(q, k, v, 64, 0.0)
    mask = np.asarray(ref.block_causal_mask(4))
    np.testing.assert_allclose(np.asarray(oam0)[:, mask],
                               np.asarray(sam)[:, mask], atol=1e-6)


def test_value_logmag_kernel():
    rng = np.random.default_rng(4)
    v = jnp.asarray(rng.normal(size=(2, 256, 32)).astype(np.float32))
    got = metric.value_block_logmag(v, 64)
    want = np.log(np.linalg.norm(np.asarray(v), axis=-1) + 1e-12)
    want = want.reshape(2, 4, 64).max(-1)
    np.testing.assert_allclose(np.asarray(got), want, atol=1e-5)


def test_oam_prefers_high_magnitude_values():
    """Paper §2.2: a moderate-score block with a huge ||V|| must outrank a
    slightly-higher-score block with tiny ||V|| under OAM but not SAM."""
    rng = np.random.default_rng(5)
    h, n, dh, b = 1, 256, 16, 64
    q = jnp.asarray(rng.normal(size=(h, n, dh)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(h, n, dh)).astype(np.float32))
    v = rng.normal(size=(h, n, dh)).astype(np.float32)
    v[:, 64:128] *= 50.0    # block 1: high-energy values
    v[:, 128:192] *= 0.01   # block 2: negligible values
    v = jnp.asarray(v)
    sam = np.asarray(ref.oam_block_scores(q, k, v, b, 0.0))
    oam = np.asarray(ref.oam_block_scores(q, k, v, b, 1.0))
    # under OAM, block 1's advantage over block 2 must grow for row 3
    gap_sam = sam[0, 3, 1] - sam[0, 3, 2]
    gap_oam = oam[0, 3, 1] - oam[0, 3, 2]
    assert gap_oam > gap_sam + 1.0
