"""Task-generator invariants: every family must emit structurally valid,
deterministic, *solvable* samples — the eval harness depends on the layout
contract ([BOS] body [QUERY] q [AMARK] answer [END])."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import tasks

ALL_FAMILIES = (
    list(tasks.FAMILIES) + list(tasks.RULER_TASKS) + ["copy", "qa_multi"]
)


@settings(deadline=None, max_examples=24)
@given(
    family=st.sampled_from(ALL_FAMILIES),
    n_ctx=st.sampled_from([128, 256, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_sample_structure(family, n_ctx, seed):
    rng = np.random.default_rng(seed)
    s = tasks.gen_sample(family, rng, n_ctx)
    assert s.ids.shape == (n_ctx,)
    assert s.ids.dtype == np.int32
    assert (s.ids >= 0).all() and (s.ids < tasks.VOCAB_SIZE).all()
    assert s.ids[0] == tasks.BOS
    if family == "copy":
        assert s.answer_len == (n_ctx - 2) // 2
    elif family == "cp":
        assert s.answer_len == min(16, (n_ctx - 2) // 2)
    else:
        assert 0 < s.answer_len <= 8
    assert 0 < s.answer_start < n_ctx
    # answer tokens are in range and the mask covers exactly them
    ans = s.ids[s.answer_start:s.answer_start + s.answer_len]
    assert (ans != tasks.PAD).all()
    on = np.flatnonzero(s.loss_mask == 1.0)
    assert on.min() == s.answer_start
    if family not in ("copy", "cp", "qa_multi"):
        assert on.max() == s.answer_start + s.answer_len - 1
        # QA layout: END closes the sequence
        assert s.ids[n_ctx - 1] == tasks.END
        assert s.ids[s.answer_start - 1] == tasks.AMARK


@settings(deadline=None, max_examples=12)
@given(
    family=st.sampled_from(ALL_FAMILIES),
    seed=st.integers(0, 2**31 - 1),
)
def test_determinism(family, seed):
    a = tasks.gen_sample(family, np.random.default_rng(seed), 256)
    b = tasks.gen_sample(family, np.random.default_rng(seed), 256)
    assert (a.ids == b.ids).all()
    assert a.answer_start == b.answer_start


def _find_sub(hay, needle):
    n = len(needle)
    for i in range(len(hay) - n + 1):
        if (hay[i:i + n] == needle).all():
            return i
    return -1


@pytest.mark.parametrize("family", ["syn", "needle", "multikey"])
def test_needle_families_are_solvable(family):
    """The queried fact must appear verbatim in the context body."""
    rng = np.random.default_rng(7)
    for _ in range(8):
        s = tasks.gen_sample(family, rng, 256)
        ids = s.ids
        # query = [KEY, key] right after QUERY
        qpos = _find_sub(ids, np.asarray([tasks.QUERY], np.int32))
        key = ids[qpos + 2]
        ans = ids[s.answer_start:s.answer_start + s.answer_len]
        fact = np.asarray([tasks.KEY, key, tasks.IS, *ans], np.int32)
        where = _find_sub(ids[:qpos], fact)
        assert where >= 0, "queried fact missing from the context"


def test_vt_chain_resolvable():
    rng = np.random.default_rng(9)
    for _ in range(8):
        s = tasks.gen_sample("vt", rng, 256)
        ids = s.ids
        # walk REF chain from the queried name down to a KEY..IS fact
        qpos = _find_sub(ids, np.asarray([tasks.QUERY], np.int32))
        name = ids[qpos + 2]
        seen = set()
        for _hop in range(8):
            assert name not in seen, "cycle in vt chain"
            seen.add(name)
            ref = _find_sub(ids[:qpos], np.asarray([tasks.KEY, name, tasks.REF], np.int32))
            if ref < 0:
                break
            name = ids[ref + 3]
        fact = _find_sub(ids[:qpos], np.asarray([tasks.KEY, name, tasks.IS], np.int32))
        assert fact >= 0
        assert ids[fact + 3] == ids[s.answer_start]


def test_majority_answer_is_modal_tag():
    rng = np.random.default_rng(11)
    for _ in range(8):
        s = tasks.gen_sample("sum", rng, 256)
        ids = s.ids
        qpos = _find_sub(ids, np.asarray([tasks.QUERY], np.int32))
        body = ids[:qpos]
        tags = body[np.flatnonzero(body[:-1] == tasks.TAG) + 1]
        vals, counts = np.unique(tags, return_counts=True)
        assert vals[counts.argmax()] == ids[s.answer_start]


def test_copy_sample_halves_match():
    rng = np.random.default_rng(3)
    s = tasks.gen_sample("copy", rng, 128)
    half = (128 - 2) // 2
    assert (s.ids[1:1 + half] == s.ids[half + 2:2 * half + 2]).all()
    assert s.loss_mask[half + 2:2 * half + 2].all()


def test_cp_answer_is_copy_tail():
    rng = np.random.default_rng(4)
    s = tasks.gen_sample("cp", rng, 256)
    half = (256 - 2) // 2
    # answer span = last 16 copied tokens, mirroring the first half's tail
    src = s.ids[1 + half - 16:1 + half]
    assert (s.ids[s.answer_start:s.answer_start + 16] == src).all()


def test_copy_variable_offset_variant():
    rng = np.random.default_rng(5)
    for _ in range(6):
        s = tasks.gen_copy(rng, 256, variable=True)
        l = s.answer_len
        # copied half matches the l tokens before SEP
        sep = s.answer_start - 1
        assert s.ids[sep] == tasks.SEP
        assert (s.ids[sep - l:sep] == s.ids[s.answer_start:s.answer_start + l]).all()


def test_gen_batch_shapes_and_mix():
    rng = np.random.default_rng(5)
    ids, mask = tasks.gen_batch(rng, ["syn", "copy"], 256, 6)
    assert ids.shape == (6, 256) and mask.shape == (6, 256)
    assert mask.max() == 1.0
    assert (mask >= 0).all()


def test_eval_set_deterministic_across_calls():
    a = tasks.gen_eval_set("md1", seed=42, n_ctx=256, count=4)
    b = tasks.gen_eval_set("md1", seed=42, n_ctx=256, count=4)
    for x, y in zip(a, b):
        assert (x.ids == y.ids).all()
