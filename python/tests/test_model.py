"""L2 model invariants: forward shapes, attention-method plumbing, budget
semantics, parameter flatten/unflatten round-trip, loss masking."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile import tasks

CFG = M.ModelConfig(d_model=64, n_layers=2, n_heads=2, n_kv_heads=1,
                    d_ff=96, block=32)


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, seed=0)


def ids_of(n):
    rng = np.random.default_rng(0)
    return jnp.asarray(rng.integers(16, 96, n), jnp.int32)


def test_forward_shapes(params):
    n = 128
    logits, bud, hidden = M.forward(CFG, params, ids_of(n), method="jnp")
    assert logits.shape == (n, CFG.vocab_size)
    assert hidden is None
    assert float(bud) == 1.0


def test_forward_collect_hidden(params):
    n = 128
    _, _, hidden = M.forward(CFG, params, ids_of(n), method="jnp",
                             collect_hidden=True)
    assert hidden.shape == (CFG.n_layers, n, CFG.d_model)
    assert bool(jnp.isfinite(hidden).all())


@pytest.mark.parametrize("method,hp", [
    ("dense", {}),
    ("stem", {"k_start": 3.0, "mu": 0.7, "beta": 0.2}),
    ("streaming", {"sink_blocks": 1, "local_blocks": 2}),
    ("xattn", {"tau": 0.9}),
    ("minference", {"n_vertical": 2, "n_slash": 2}),
    ("flexprefill", {"gamma": 0.9, "entropy_thresh": 0.35}),
    ("segment", {"seg_lo": 0, "seg_hi": 2, "k_seg": 2, "ratio": 0.0}),
])
def test_every_method_runs_and_reports_budget(params, method, hp):
    n = 128
    logits, bud, _ = M.forward(CFG, params, ids_of(n), method=method,
                               hparams=hp)
    assert logits.shape == (n, CFG.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{method} produced non-finite"
    b = float(bud)
    assert 0.0 < b <= 1.0
    if method == "dense":
        assert b == 1.0


def test_stem_full_budget_matches_dense(params):
    """k_start = nblk, mu=1, beta irrelevant -> selection is all causal
    blocks -> logits must equal the dense kernel's."""
    n = 128
    nblk = n // CFG.block
    a, _, _ = M.forward(CFG, params, ids_of(n), method="dense")
    b, bud, _ = M.forward(CFG, params, ids_of(n), method="stem",
                          hparams={"k_start": float(nblk), "mu": 1.0,
                                   "beta": 0.0})
    assert float(bud) == 1.0
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)


def test_sparse_error_monotone_in_budget(params):
    n = 256
    dense, _, _ = M.forward(CFG, params, ids_of(n), method="jnp")
    errs = []
    for ks in [2.0, 4.0, 8.0]:
        sp, _, _ = M.forward(CFG, params, ids_of(n), method="stem",
                             hparams={"k_start": ks, "mu": 0.7, "beta": 0.2})
        errs.append(float(jnp.mean((sp - dense) ** 2)))
    assert errs[0] >= errs[1] >= errs[2], errs


def test_param_flatten_roundtrip(params):
    flat = M.flatten_params(CFG, params)
    spec = M.param_spec(CFG)
    assert len(flat) == len(spec)
    for a, (_, shape) in zip(flat, spec):
        assert tuple(a.shape) == tuple(shape)
    back = M.unflatten_params(CFG, flat)
    for lyr_a, lyr_b in zip(params["layers"], back["layers"]):
        for k in lyr_a:
            assert lyr_a[k] is lyr_b[k] or bool((lyr_a[k] == lyr_b[k]).all())


def test_lm_loss_masking(params):
    """Loss must ignore masked positions entirely."""
    rng = np.random.default_rng(1)
    ids, mask = tasks.gen_batch(rng, ["syn"], 128, 2)
    base = float(M.lm_loss(CFG, params, jnp.asarray(ids), jnp.asarray(mask)))
    # corrupt a masked-out position — loss unchanged
    ids2 = ids.copy()
    off = np.flatnonzero(mask[0] == 0.0)
    ids2[0, off[len(off) // 2]] = 17
    pert = float(M.lm_loss(CFG, params, jnp.asarray(ids2), jnp.asarray(mask)))
    # answer positions sit at the tail; corrupting filler may still shift
    # logits of later positions, so compare only when the corrupted index
    # precedes every unmasked target... simplest: corrupt the final PAD.
    assert np.isfinite(base) and np.isfinite(pert)


def test_rope_position_dependence(params):
    """Swapping two context tokens must change the final-position logits:
    a position-blind (bag-of-words) attention would be permutation
    invariant, so this catches broken RoPE wiring. (Comparing logits of
    identical tokens at different positions is NOT a valid test: identical
    value vectors average to the same output under any attention weights.)"""
    n = 64
    rng = np.random.default_rng(0)
    base = rng.integers(16, 96, n).astype(np.int32)
    swapped = base.copy()
    swapped[3], swapped[7] = swapped[7], swapped[3]
    a, _, _ = M.forward(CFG, params, jnp.asarray(base), method="jnp")
    b, _, _ = M.forward(CFG, params, jnp.asarray(swapped), method="jnp")
    assert not np.allclose(np.asarray(a[-1]), np.asarray(b[-1]), atol=1e-6)


@settings(deadline=None, max_examples=6)
@given(seed=st.integers(0, 2**31 - 1))
def test_gqa_expand_consistency(seed):
    """jnp-path logits equal the kernel-path dense logits on random ids."""
    rng = np.random.default_rng(seed)
    params = M.init_params(CFG, seed=seed % 1000)
    ids = jnp.asarray(rng.integers(16, 96, 64), jnp.int32)
    a, _, _ = M.forward(CFG, params, ids, method="jnp")
    b, _, _ = M.forward(CFG, params, ids, method="dense")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-4)
