"""Synthetic long-context task suite (LongBench / RULER proxies).

The paper evaluates on LongBench task families (CC, FSL, MD1, MD2, SUM,
SYN) and RULER length-stress suites with 8B backbones. Neither the models
nor the datasets fit this CPU testbed, so — per the substitution rule —
each family is replaced by a synthetic proxy that stresses the same
capability class on a small trained-from-scratch transformer:

  family | proxy                      | capability exercised
  -------|----------------------------|--------------------------------------
  CC     | function-body completion   | repo-level retrieval + local syntax
  FSL    | induction pairs            | few-shot pattern matching
  MD1    | multi-doc fact lookup      | cross-document retrieval
  MD2    | two-hop doc chain          | multi-hop aggregation
  SUM    | majority-tag counting      | global aggregation over the context
  SYN    | needle-in-a-haystack       | exact long-range recall
  RULER  | {needle, multikey needle, variable tracking} at several lengths

Every sample is a token-id sequence of exactly `n_ctx` positions laid out

    [BOS] <context ...> [QUERY] <query> [AMARK] <answer tokens> [PAD ...]

so *one prefill pass* scores it: the model is teacher-forced and judged by
argmax exact-match on the answer positions (logits at p-1 predict token p).
Accuracy deltas between attention methods under equal budget — the paper's
actual claim — are measurable this way without any decode loop.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

# --- vocabulary -------------------------------------------------------------

PAD, BOS, SEP, QUERY, AMARK, DOC, KEY, IS, TAG, FN, REF, END = range(12)
WORD0 = 16
VOCAB_SIZE = 96
N_WORDS = VOCAB_SIZE - WORD0  # 80 "word" ids

SPECIAL_NAMES = {
    PAD: "<pad>", BOS: "<bos>", SEP: ";", QUERY: "<q>", AMARK: "=>",
    DOC: "<doc>", KEY: "<key>", IS: "<is>", TAG: "<tag>", FN: "<fn>",
    REF: "<ref>", END: "<end>",
}

FAMILIES = ("cc", "cp", "fsl", "md1", "md2", "sum", "syn")
RULER_TASKS = ("needle", "multikey", "vt", "cp")


def detok(ids) -> str:
    """Human-readable rendering (debugging only)."""
    out = []
    for t in ids:
        t = int(t)
        out.append(SPECIAL_NAMES.get(t, f"w{t - WORD0}" if t >= WORD0 else f"?{t}"))
    return " ".join(out)


@dataclass
class Sample:
    ids: np.ndarray            # [n_ctx] int32
    loss_mask: np.ndarray      # [n_ctx] float32 — 1 where LM loss applies
    answer_start: int          # first answer token position
    answer_len: int
    family: str
    meta: dict = field(default_factory=dict)


def _words(rng: np.random.Generator, n: int, exclude=()) -> np.ndarray:
    pool = np.setdiff1d(np.arange(WORD0, VOCAB_SIZE), np.asarray(exclude, int))
    return rng.choice(pool, size=n, replace=len(pool) < n)


def _insert_many(body: list[int], stmts: list[list[int]], rng) -> list[int]:
    """Insert atomic statements at random positions without splitting each
    other: positions are drawn in the ORIGINAL coordinate space, statements
    are assigned to ascending positions (preserving list order, e.g. vt
    chains read left-to-right), and insertion proceeds from the highest
    position down so earlier inserts never land inside later ones.
    """
    # keep clear of the tail: _finish truncates body[:room] to make space
    # for the query/answer, so statements inserted in the last ~64 tokens
    # would be cut and the sample rendered unsolvable.
    hi = max(1, len(body) - 64)
    pos = sorted(int(rng.integers(0, hi)) for _ in stmts)
    out = list(body)
    for p, stmt in sorted(zip(pos, stmts), key=lambda t: -t[0]):
        out[p:p] = stmt
    return out


def _finish(body: list[int], query: list[int], answer: list[int],
            n_ctx: int, family: str, rng, meta=None) -> Sample:
    """Assemble [BOS] body [QUERY] query [AMARK] answer [END] + PAD."""
    tail = [QUERY] + query + [AMARK] + answer + [END]
    room = n_ctx - 1 - len(tail)
    assert room >= 0, f"context too small: n_ctx={n_ctx} tail={len(tail)}"
    body = body[:room]
    # top up with filler so the answer sits near the end at every length
    filler = _words(rng, max(0, room - len(body)))
    seq = [BOS] + body + list(filler) + tail
    ids = np.asarray(seq, np.int32)
    assert ids.shape[0] == n_ctx
    ans_start = n_ctx - 1 - len(answer)  # position of first answer token
    mask = np.zeros(n_ctx, np.float32)
    # Loss on answer tokens ONLY. Filler tokens are uniform-random, so LM
    # loss on them is pure gradient noise that empirically drowns the task
    # signal at these batch sizes (sees EXPERIMENTS.md §Training).
    mask[ans_start:n_ctx - 1] = 1.0
    return Sample(ids, mask, ans_start, len(answer), family, meta or {})


# --- generators -------------------------------------------------------------


def gen_needle(rng, n_ctx: int, n_answer: int = 1) -> Sample:
    """SYN / RULER-needle: one KEY..IS..value fact buried in filler."""
    key = int(_words(rng, 1)[0])
    vals = [int(x) for x in _words(rng, n_answer, exclude=[key])]
    body = list(_words(rng, n_ctx))
    pos = int(rng.integers(0, max(1, len(body) - 32)))
    body[pos:pos] = [KEY, key, IS, *vals, SEP]
    return _finish(body, [KEY, key], vals, n_ctx, "syn", rng,
                   {"depth": pos / max(1, n_ctx)})


def gen_multikey(rng, n_ctx: int, n_keys: int = 4) -> Sample:
    """RULER multikey: several facts, query one (distractor robustness)."""
    keys = _words(rng, n_keys)
    vals = _words(rng, n_keys, exclude=keys)
    stmts = [[KEY, int(k), IS, int(v), SEP] for k, v in zip(keys, vals)]
    body = _insert_many(list(_words(rng, n_ctx)), stmts, rng)
    pick = int(rng.integers(0, n_keys))
    return _finish(body, [KEY, int(keys[pick])], [int(vals[pick])],
                   n_ctx, "syn", rng, {"n_keys": n_keys})


def gen_vt(rng, n_ctx: int, hops: int = 2) -> Sample:
    """RULER variable tracking: KEY b REF a chains; resolve the chain."""
    names = _words(rng, hops + 1)
    val = int(_words(rng, 1, exclude=names)[0])
    stmts = [[KEY, int(names[0]), IS, val, SEP]]
    for h in range(1, hops + 1):
        stmts.append([KEY, int(names[h]), REF, int(names[h - 1]), SEP])
    # _insert_many keeps list order at ascending positions, so the chain
    # reads left-to-right and no statement can split another.
    body = _insert_many(list(_words(rng, n_ctx)), stmts, rng)
    return _finish(body, [KEY, int(names[-1])], [val], n_ctx, "syn", rng,
                   {"hops": hops})


def gen_induction(rng, n_ctx: int, n_pairs: int = 12) -> Sample:
    """FSL: (a => b) few-shot pairs, one queried at the end."""
    a = _words(rng, n_pairs)
    b = _words(rng, n_pairs)
    stmts = [[int(x), AMARK, int(y), SEP] for x, y in zip(a, b)]
    out = _insert_many(list(_words(rng, n_ctx)), stmts, rng)
    pick = int(rng.integers(0, n_pairs))
    return _finish(out, [int(a[pick])], [int(b[pick])], n_ctx, "fsl", rng)


def gen_multidoc(rng, n_ctx: int, n_docs: int = 4, hop2: bool = False) -> Sample:
    """MD1 (hop2=False): DOC d ... KEY t IS f — query doc id, answer fact.
    MD2 (hop2=True): doc A holds REF to doc B; answer is B's fact."""
    docs = _words(rng, n_docs)
    facts = _words(rng, n_docs, exclude=docs)
    body: list[int] = []
    doc_words = max(8, (n_ctx // (n_docs + 2)) - 8)
    order = rng.permutation(n_docs)
    for d in order:
        body += [DOC, int(docs[d]), SEP]
        body += [int(w) for w in _words(rng, doc_words)]
        body += [KEY, int(docs[d]), IS, int(facts[d]), SEP]
    if not hop2:
        pick = int(rng.integers(0, n_docs))
        return _finish(body, [DOC, int(docs[pick])], [int(facts[pick])],
                       n_ctx, "md1", rng)
    # two-hop: a bridge statement "KEY docA REF docB"; query docA via REF.
    # Inserted at a doc boundary so it cannot split a KEY..IS fact.
    a, bdoc = rng.choice(n_docs, 2, replace=False)
    bridge = [KEY, int(docs[a]), REF, int(docs[bdoc]), SEP]
    starts = [i for i in range(len(body)) if body[i] == DOC] + [len(body)]
    pos = int(starts[int(rng.integers(0, len(starts)))])
    body[pos:pos] = bridge
    return _finish(body, [REF, int(docs[a])], [int(facts[bdoc])],
                   n_ctx, "md2", rng)


def gen_majority(rng, n_ctx: int, n_tags: int = 3) -> Sample:
    """SUM proxy: tags sprinkled through the context; answer = most
    frequent tag (global aggregation, no single needle suffices)."""
    tags = _words(rng, n_tags)
    win = int(rng.integers(0, n_tags))
    occ_win = int(rng.integers(6, 9))
    stmts = []
    for t_i, tag in enumerate(tags):
        occ = occ_win if t_i == win else int(rng.integers(1, 3))
        stmts += [[TAG, int(tag)]] * occ
    body = _insert_many(list(_words(rng, n_ctx)), stmts, rng)
    return _finish(body, [TAG], [int(tags[win])], n_ctx, "sum", rng)


def gen_codecomp(rng, n_ctx: int, n_fns: int = 4, body_len: int = 3) -> Sample:
    """CC proxy: function definitions FN f SEP b1 b2 b3 END; a later call
    site must reproduce the first `body_len` body tokens."""
    fns = _words(rng, n_fns)
    bodies = [_words(rng, body_len, exclude=fns) for _ in range(n_fns)]
    stmts = [[FN, int(f), SEP, *[int(x) for x in bb], END]
             for f, bb in zip(fns, bodies)]
    body = _insert_many(list(_words(rng, n_ctx)), stmts, rng)
    pick = int(rng.integers(0, n_fns))
    return _finish(body, [FN, int(fns[pick])],
                   [int(x) for x in bodies[pick]], n_ctx, "cc", rng)


def gen_copy(rng, n_ctx: int, variable: bool = False) -> Sample:
    """Training-only: dense-supervision copy block.

    Fixed layout (default): [BOS] w(half) [SEP] w(half) — the recipe the
    backbone demonstrably learns at every length rung within the build
    budget (EXPERIMENTS.md §Training). ~n/2 supervised positions per
    sample vs the QA families' 1-3.

    `variable=True` randomizes both the copied length and a filler prefix
    to force content-based induction instead of the positional shortcut;
    calibration showed it does NOT crack within this testbed's budget, so
    it is available for longer-budget runs but off by default.
    """
    if variable:
        max_l = (n_ctx - 2) // 2
        lo = max(4, n_ctx // 5)
        l = int(rng.integers(lo, max_l + 1))
        f = int(rng.integers(0, n_ctx - 2 - 2 * l + 1))
        w = _words(rng, l)
        seq = np.concatenate([[BOS], _words(rng, f), w, [SEP], w]).astype(np.int32)
        start = 2 + f + l
    else:
        l = (n_ctx - 2) // 2
        w = _words(rng, l)
        seq = np.concatenate([[BOS], w, [SEP], w]).astype(np.int32)
        start = l + 2
    ids = np.zeros(n_ctx, np.int32)
    ids[: len(seq)] = seq
    mask = np.zeros(n_ctx, np.float32)
    mask[start : start + l] = 1.0
    return Sample(ids, mask, start, l, "copy")


def gen_cp(rng, n_ctx: int, answer_len: int = 16) -> Sample:
    """CP — long-range copy completion ([BOS] w(half) [SEP] w(half)).

    The CC-proxy variant the trained backbone is actually competent at
    (EXPERIMENTS.md §Training documents why the sparse-supervision QA
    families stay at chance on this testbed): reproducing a long block
    seen half a context ago is dense retrieval across ~n/2 positions —
    the capability class of LongBench code-completion — and is exactly
    the signal block-sparse selection can destroy (prune the source
    blocks and the copy fails). Scored on the LAST `answer_len` copied
    tokens, the positions whose sources sit deepest in the context.
    """
    half = (n_ctx - 2) // 2
    w = _words(rng, half)
    seq = np.concatenate([[BOS], w, [SEP], w]).astype(np.int32)
    ids = np.zeros(n_ctx, np.int32)
    ids[: len(seq)] = seq
    end = 2 * half + 2
    ans = min(answer_len, half)
    mask = np.zeros(n_ctx, np.float32)
    mask[end - ans : end] = 1.0
    return Sample(ids, mask, end - ans, ans, "cp")


def gen_qa_multi(rng, n_ctx: int, n_facts: int = 6, n_queries: int = 4) -> Sample:
    """Training-only: multi-query needle — one context, several QA pairs.

    Densifies supervision in the exact eval format ([QUERY] KEY k [AMARK]
    v [END] tail): n_queries answer tokens per sample instead of 1, which
    is what lets the QA format crack within the build budget. Eval samples
    (single query) are a strict sub-format.
    """
    keys = _words(rng, n_facts)
    vals = _words(rng, n_facts, exclude=keys)
    stmts = [[KEY, int(k), IS, int(v), SEP] for k, v in zip(keys, vals)]
    tail: list[int] = []
    picks = rng.choice(n_facts, size=min(n_queries, n_facts), replace=False)
    for p in picks:
        tail += [QUERY, KEY, int(keys[p]), AMARK, int(vals[p]), END]
    room = n_ctx - 1 - len(tail)
    body = _insert_many(list(_words(rng, room)), stmts, rng)[:room]
    seq = [BOS] + body + tail
    ids = np.asarray(seq[:n_ctx], np.int32)
    mask = np.zeros(n_ctx, np.float32)
    first_ans = None
    for i, t in enumerate(seq[:n_ctx]):
        if t == AMARK and i + 1 < n_ctx:
            mask[i + 1] = 1.0
            if first_ans is None:
                first_ans = i + 1
    return Sample(ids, mask, first_ans or n_ctx - 2, 1, "qa_multi")


GENERATORS = {
    "copy": gen_copy,
    "cp": gen_cp,
    "qa_multi": gen_qa_multi,
    "syn": gen_needle,
    "fsl": gen_induction,
    "md1": lambda rng, n: gen_multidoc(rng, n, hop2=False),
    "md2": lambda rng, n: gen_multidoc(rng, n, hop2=True),
    "sum": gen_majority,
    "cc": gen_codecomp,
    "needle": gen_needle,
    "multikey": gen_multikey,
    "vt": gen_vt,
}


def gen_sample(family: str, rng, n_ctx: int) -> Sample:
    return GENERATORS[family](rng, n_ctx)


def gen_batch(rng, families, n_ctx: int, batch: int):
    """Training batch: (ids [B, N], loss_mask [B, N])."""
    ids = np.zeros((batch, n_ctx), np.int32)
    mask = np.zeros((batch, n_ctx), np.float32)
    for b in range(batch):
        fam = families[int(rng.integers(0, len(families)))]
        s = gen_sample(fam, rng, n_ctx)
        ids[b] = s.ids
        mask[b] = s.loss_mask
    return ids, mask


def gen_eval_set(family: str, seed: int, n_ctx: int, count: int):
    """Deterministic eval set for export to the rust eval harness."""
    rng = np.random.default_rng(seed)
    return [gen_sample(family, rng, n_ctx) for _ in range(count)]
