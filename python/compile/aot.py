"""AOT pipeline: train → export weights → lower prefill graphs to HLO text.

Run once via `make artifacts` (idempotent — skips work whose outputs are
newer than this package). Produces, under `artifacts/`:

  manifest.json            model config, parameter spec, module table,
                           serving defaults, eval-set index
  weights_base.stw         trained dense backbone (custom .stw format)
  weights_native.stw       backbone trained WITH uniform block-top-k
                           (the Table-3 "training-based sparse" stand-in)
  train_log_{base,native}.json   loss curves (EXPERIMENTS.md §E2E)
  modules/<name>.hlo.txt   one per (graph, seqlen bucket) — HLO TEXT, not
                           serialized protos (xla_extension 0.5.1 rejects
                           jax>=0.5 64-bit instruction ids; the text parser
                           reassigns ids — see /opt/xla-example/README.md)
  eval/<family>_<n>.json   deterministic eval sets for the rust harness
  golden/*.json            cross-language golden vectors (pytest == rust)

.stw format ("stem weights"): 8-byte magic "STEMWTS0", then u32 little-
endian header length, then a JSON header [{name, dtype, shape, offset,
nbytes}...], then raw little-endian tensor bytes at 16-byte alignment.

Module input signature (everything is a runtime input; Python never runs
at serve time):
  params...                in `param_spec` order (f32)
  ids                      i32[N]
  <scalars>                method hyper-parameters, each shape-(1,) f32/i32
Outputs (tupled): logits f32[N, V], budget_fraction f32[1]
  (+ hidden f32[L, N, d] for diag_* graphs).
`decode_step_<n>` graphs take no scalars — serving defaults are baked in
at lowering time; the rust decode backend feeds the padded token history
and reads the final logits row (see rust/src/decode/backend.rs).
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from . import tasks, train
from .kernels import ref

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

PREFILL_BUCKETS = (512, 1024, 2048)
DIAG_BUCKETS = (1024, 2048)
DECODE_BUCKETS = (512, 1024, 2048)   # decode_step per-step modules
EVAL_COUNT = 24          # samples per (family, bucket)
RULER_COUNT = 24

F32, I32 = jnp.float32, jnp.int32


# --- .stw weights writer -----------------------------------------------------


def write_stw(path: str, tensors: list[tuple[str, np.ndarray]]) -> None:
    header = []
    offset = 0
    blobs = []
    for name, arr in tensors:
        arr = np.ascontiguousarray(arr)
        pad = (-offset) % 16
        offset += pad
        blobs.append(b"\x00" * pad + arr.tobytes())
        header.append({
            "name": name,
            "dtype": str(arr.dtype),
            "shape": list(arr.shape),
            "offset": offset,
            "nbytes": arr.nbytes,
        })
        offset += arr.nbytes
    hjson = json.dumps(header).encode()
    with open(path, "wb") as f:
        f.write(b"STEMWTS0")
        f.write(struct.pack("<I", len(hjson)))
        f.write(hjson)
        for b in blobs:
            f.write(b)


# --- HLO text lowering -------------------------------------------------------


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def scalar_in(x, dtype):
    """Runtime hyper-parameter: shape-(1,) array, read as x[0] in-graph."""
    return jnp.asarray(x, dtype).reshape(1)


def build_graph(cfg: M.ModelConfig, n: int, kind: str):
    """Returns (fn, example_args_after_params, scalar_names).

    `fn(params_flat..., ids, *scalars)`; all scalars shape (1,).
    """
    nspec = len(M.param_spec(cfg))

    def run(flat, ids, method, hp, collect_hidden):
        params = M.unflatten_params(cfg, list(flat))
        logits, bud, hidden = M.forward(
            cfg, params, ids, method=method, hparams=hp,
            collect_hidden=collect_hidden)
        out = (logits, bud.reshape(1))
        if collect_hidden:
            out = out + (hidden,)
        return out

    diag = kind.startswith("diag_")
    if kind == "decode_step":
        # Per-step decode graph (rust `DecodeBackend::Engine`): a full
        # stem forward over the PAD-padded token history whose last row
        # of logits is the next-token distribution. The rust caller
        # passes NO scalars (decode/backend.rs executes
        # `prefill(kind="decode_step", scalars=[])`), so this bucket's
        # serving defaults are baked into the graph as constants.
        sd = serving_defaults(n, cfg.block)
        # as 0-d jnp scalars, matching the traced-scalar prefill path
        hp = {"k_start": jnp.float32(sd["k_start"]),
              "mu": jnp.float32(sd["mu"]), "beta": jnp.float32(sd["beta"])}
        def fn(*args):
            flat, ids = args[:nspec], args[nspec]
            return run(flat, ids, "stem", hp, False)
        return fn, []
    base = kind[5:] if diag else kind[8:]          # strip diag_/prefill_

    if base == "dense":
        scalars = []
        def fn(*args):
            flat, ids = args[:nspec], args[nspec]
            return run(flat, ids, "dense", {}, diag)
    elif base == "stem":
        scalars = [("k_start", F32), ("mu", F32), ("beta", F32)]
        def fn(*args):
            flat, ids = args[:nspec], args[nspec]
            ks, mu, beta = args[nspec + 1:]
            hp = {"k_start": ks[0], "mu": mu[0], "beta": beta[0]}
            return run(flat, ids, "stem", hp, diag)
    elif base == "streaming":
        scalars = [("sink_blocks", I32), ("local_blocks", I32)]
        def fn(*args):
            flat, ids = args[:nspec], args[nspec]
            s, l = args[nspec + 1:]
            return run(flat, ids, "streaming",
                       {"sink_blocks": s[0], "local_blocks": l[0]}, diag)
    elif base == "xattn":
        scalars = [("tau", F32)]
        def fn(*args):
            flat, ids = args[:nspec], args[nspec]
            (tau,) = args[nspec + 1:]
            return run(flat, ids, "xattn", {"tau": tau[0]}, diag)
    elif base == "minference":
        scalars = [("n_vertical", I32), ("n_slash", I32)]
        def fn(*args):
            flat, ids = args[:nspec], args[nspec]
            nv, ns = args[nspec + 1:]
            return run(flat, ids, "minference",
                       {"n_vertical": nv[0], "n_slash": ns[0]}, diag)
    elif base == "flexprefill":
        scalars = [("gamma", F32), ("entropy_thresh", F32)]
        def fn(*args):
            flat, ids = args[:nspec], args[nspec]
            g, e = args[nspec + 1:]
            return run(flat, ids, "flexprefill",
                       {"gamma": g[0], "entropy_thresh": e[0]}, diag)
    elif base == "segment":
        scalars = [("seg_lo", I32), ("seg_hi", I32), ("k_seg", I32),
                   ("ratio", F32)]
        def fn(*args):
            flat, ids = args[:nspec], args[nspec]
            lo, hi, kseg, ratio = args[nspec + 1:]
            return run(flat, ids, "segment",
                       {"seg_lo": lo[0], "seg_hi": hi[0],
                        "k_seg": kseg[0], "ratio": ratio[0]}, diag)
    else:
        raise ValueError(kind)
    return fn, scalars


def lower_module(cfg: M.ModelConfig, kind: str, n: int, out_dir: str):
    fn, scalars = build_graph(cfg, n, kind)
    spec = M.param_spec(cfg)
    args = [jax.ShapeDtypeStruct(s, F32) for _, s in spec]
    args.append(jax.ShapeDtypeStruct((n,), I32))
    args += [jax.ShapeDtypeStruct((1,), dt) for _, dt in scalars]
    t0 = time.time()
    lowered = jax.jit(fn).lower(*args)
    text = to_hlo_text(lowered)
    name = f"{kind}_{n}"
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)
    print(f"[aot] lowered {name}: {len(text)/1e6:.2f} MB HLO text "
          f"({time.time()-t0:.1f}s)", flush=True)
    return {
        "name": name,
        "kind": kind,
        "n_ctx": n,
        "file": f"modules/{name}.hlo.txt",
        "scalars": [{"name": s, "dtype": "f32" if dt == F32 else "i32"}
                    for s, dt in scalars],
        "outputs": (["logits", "budget", "hidden"]
                    if kind.startswith("diag_") else ["logits", "budget"]),
    }


# --- serving defaults (paper §3.1 scaled to this testbed) -------------------


def serving_defaults(n: int, block: int) -> dict:
    nblk = n // block
    frac = 0.25 if n <= 1024 else 0.2     # paper: 0.2 @8-16k, 0.1 >16k
    k_start = max(4.0, frac * nblk)
    return {
        "n_ctx": n,
        "n_blocks": nblk,
        "k_start": k_start,
        "mu": 0.7,
        "beta": 0.2,
        "k_uni_matched": k_start * (1 + 0.7) / 2,
        "streaming": {"sink_blocks": 1, "local_blocks": 3},
        "xattn": {"tau": 0.9},
        "minference": {"n_vertical": max(2, int(0.12 * nblk)),
                       "n_slash": max(2, int(0.12 * nblk))},
        "flexprefill": {"gamma": 0.9, "entropy_thresh": 0.35},
    }


# --- golden vectors ----------------------------------------------------------


def export_goldens(cfg: M.ModelConfig, params, out_dir: str):
    """Cross-language goldens: tiny tensors with exact expected outputs."""
    rng = np.random.default_rng(7)
    h, hk, n, dh, b = 2, 1, 128, 16, 64
    q = rng.normal(size=(h, n, dh)).astype(np.float32)
    k = rng.normal(size=(hk, n, dh)).astype(np.float32)
    v = rng.normal(size=(hk, n, dh)).astype(np.float32)
    nblk = n // b
    idx = np.zeros((h, nblk, nblk), np.int32)
    cnt = np.zeros((h, nblk), np.int32)
    for hh in range(h):
        for i in range(nblk):
            c = i + 1 if i == 0 else 1 + rng.integers(0, i + 1)
            sel = rng.choice(i + 1, size=c, replace=False)
            idx[hh, i, :c] = sel
            cnt[hh, i] = c
    out = ref.block_sparse_attention(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
        jnp.asarray(idx), jnp.asarray(cnt), b)
    oam = ref.oam_block_scores(
        jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), b, 0.2, 16)
    golden = {
        "block": b, "h": h, "hk": hk, "n": n, "dh": dh,
        "q": q.ravel().tolist(), "k": k.ravel().tolist(),
        "v": v.ravel().tolist(),
        "indices": idx.ravel().tolist(), "counts": cnt.ravel().tolist(),
        "attention_out": np.asarray(out).ravel().tolist(),
        "oam_scores": np.asarray(oam).ravel().tolist(),
    }
    with open(os.path.join(out_dir, "kernels.json"), "w") as f:
        json.dump(golden, f)

    # model-level golden: logits of a fixed prompt through the jnp path
    n2 = 512
    s = tasks.gen_sample("syn", np.random.default_rng(11), n2)
    logits, _, _ = M.forward(cfg, params, jnp.asarray(s.ids), method="jnp")
    with open(os.path.join(out_dir, "model_dense_512.json"), "w") as f:
        json.dump({
            "ids": s.ids.tolist(),
            "answer_start": s.answer_start,
            "answer_len": s.answer_len,
            "logits_tail": np.asarray(logits)[-8:].ravel().tolist(),
            "argmax": np.asarray(logits).argmax(-1).tolist(),
        }, f)
    print("[aot] goldens written", flush=True)


# --- eval set export ---------------------------------------------------------


def export_eval_sets(out_dir: str):
    index = []
    for fam in tasks.FAMILIES:
        for n in PREFILL_BUCKETS:
            samples = tasks.gen_eval_set(fam, seed=1000 + n, n_ctx=n,
                                         count=EVAL_COUNT)
            rec = [{
                "ids": s.ids.tolist(),
                "answer_start": s.answer_start,
                "answer_len": s.answer_len,
            } for s in samples]
            fname = f"eval/{fam}_{n}.json"
            with open(os.path.join(out_dir, f"{fam}_{n}.json"), "w") as f:
                json.dump(rec, f)
            index.append({"family": fam, "suite": "longbench",
                          "n_ctx": n, "file": fname, "count": len(rec)})
    for task in tasks.RULER_TASKS:
        for n in PREFILL_BUCKETS:
            samples = tasks.gen_eval_set(task, seed=2000 + n, n_ctx=n,
                                         count=RULER_COUNT)
            rec = [{
                "ids": s.ids.tolist(),
                "answer_start": s.answer_start,
                "answer_len": s.answer_len,
            } for s in samples]
            fname = f"eval/ruler_{task}_{n}.json"
            with open(os.path.join(out_dir, f"ruler_{task}_{n}.json"),
                      "w") as f:
                json.dump(rec, f)
            index.append({"family": task, "suite": "ruler",
                          "n_ctx": n, "file": fname, "count": len(rec)})
    print(f"[aot] eval sets written ({len(index)} files)", flush=True)
    return index


# --- main --------------------------------------------------------------------


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default=ART)
    ap.add_argument("--fast", action="store_true",
                    help="tiny training schedule (CI smoke)")
    ap.add_argument("--skip-train", action="store_true")
    args = ap.parse_args()

    art = os.path.abspath(args.out)
    for sub in ("modules", "eval", "golden"):
        os.makedirs(os.path.join(art, sub), exist_ok=True)

    cfg = M.ModelConfig()

    # 1. train (or reuse) the two checkpoints -------------------------------
    # base: copy-curriculum pretrain + task finetune (train.PHASES_BASE);
    # native: finetuned FROM base with uniform block-top-k attention — the
    # DSA/InfLLMv2 "continued training with native sparsity" recipe.
    ckpts = {}
    for name, native_k in (("base", 0.0), ("native", 6.0)):
        npz = os.path.join(art, f"ckpt_{name}.npz")
        if os.path.exists(npz) or args.skip_train:
            print(f"[aot] reusing {npz}", flush=True)
            data = np.load(npz)
            flat = [jnp.asarray(data[k]) for k, _ in
                    ((n, s) for n, s in M.param_spec(cfg))]
            ckpts[name] = M.unflatten_params(cfg, flat)
            continue
        if args.fast:
            phases = (("copy", 64, 64, 30), ("tasks", 256, 8, 10))
        elif name == "base":
            phases = train.PHASES_BASE
        else:
            phases = train.PHASES_NATIVE
        params, log = train.train(
            cfg, name=name, native_k=native_k, phases=phases,
            init=ckpts.get("base") if name == "native" else None)
        ckpts[name] = params
        flat = M.flatten_params(cfg, params)
        np.savez(npz, **{n: np.asarray(a) for (n, _), a in
                         zip(M.param_spec(cfg), flat)})
        train.save_log(log, os.path.join(art, f"train_log_{name}.json"))

    # 2. weights export ------------------------------------------------------
    for name in ("base", "native"):
        flat = M.flatten_params(cfg, ckpts[name])
        write_stw(os.path.join(art, f"weights_{name}.stw"),
                  [(n, np.asarray(a)) for (n, _), a in
                   zip(M.param_spec(cfg), flat)])
    print("[aot] weights exported", flush=True)

    # 3. lower modules -------------------------------------------------------
    modules = []
    kinds_prefill = ["prefill_dense", "prefill_stem", "prefill_streaming",
                     "prefill_xattn", "prefill_minference",
                     "prefill_flexprefill"]
    for n in PREFILL_BUCKETS:
        for kind in kinds_prefill:
            modules.append(lower_module(cfg, kind, n, os.path.join(art, "modules")))
    for n in DIAG_BUCKETS:
        for kind in ("diag_dense", "diag_stem", "diag_segment"):
            modules.append(lower_module(cfg, kind, n, os.path.join(art, "modules")))
    # per-step decode graphs, one per context bucket — consumed by the
    # rust `--decode-backend engine` path (decode/backend.rs)
    for n in DECODE_BUCKETS:
        modules.append(lower_module(cfg, "decode_step", n, os.path.join(art, "modules")))

    # 4. goldens + eval sets --------------------------------------------------
    export_goldens(cfg, ckpts["base"], os.path.join(art, "golden"))
    eval_index = export_eval_sets(os.path.join(art, "eval"))

    # 5. manifest -------------------------------------------------------------
    manifest = {
        "format": 1,
        "model": cfg.to_dict(),
        "d_head": cfg.d_head,
        "param_spec": [{"name": n, "shape": list(s)}
                       for n, s in M.param_spec(cfg)],
        "weights": {"base": "weights_base.stw",
                    "native": "weights_native.stw"},
        "modules": modules,
        "eval_sets": eval_index,
        "serving_defaults": {str(n): serving_defaults(n, cfg.block)
                             for n in PREFILL_BUCKETS},
        "vocab": {"size": tasks.VOCAB_SIZE, "pad": tasks.PAD,
                  "bos": tasks.BOS, "query": tasks.QUERY,
                  "amark": tasks.AMARK, "end": tasks.END},
    }
    with open(os.path.join(art, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print("[aot] manifest written — done", flush=True)


if __name__ == "__main__":
    main()
