"""Pallas kernels for the Output-Aware Metric (paper §2.2, Algorithm 1).

Two kernels make up the "Metric Calculation" stage of Eq. (8):

  * `value_logmag_kernel` — block max-pool of log||V_j||_2 (Alg. 1 line 6),
    grid over (kv head, kv block); cost O(N d / B) per head.
  * `oam_metric_kernel` — per query block, the anti-diagonal-sampled
    routing estimate Q_i K_j^T / sqrt(d) plus beta * max(0, M_V) with the
    causal block mask (Alg. 1 lines 12-13); the anti-diagonal sampling
    reduces the quadratic routing term by B*stride.

`beta` is a runtime scalar so a single AOT'd module serves both SAM
(beta = 0) and OAM (beta > 0) as well as the Figure-5 beta sweep.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _logmag_kernel(v_ref, o_ref):
    v = v_ref[0].astype(jnp.float32)                         # [B, dh]
    mag = jnp.log(jnp.sqrt((v * v).sum(axis=-1)) + 1e-12)    # [B]
    o_ref[0, 0] = mag.max()


@functools.partial(jax.jit, static_argnames=("block",))
def value_block_logmag(v, block: int = 64):
    """[Hk, N, dh] -> [Hk, N/B] block max of log||V||_2 (Pallas)."""
    hk, n, dh = v.shape
    nblk = n // block
    return pl.pallas_call(
        _logmag_kernel,
        grid=(hk, nblk),
        in_specs=[pl.BlockSpec((1, block, dh), lambda h, j: (h, j, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda h, j: (h, j)),
        out_shape=jax.ShapeDtypeStruct((hk, nblk), jnp.float32),
        interpret=True,
    )(v)


def _oam_kernel(beta_ref, q_ref, k_ref, mv_ref, o_ref, *, block: int,
                stride: int, nblk: int, scale: float):
    i = pl.program_id(1)
    t = jax.lax.iota(jnp.int32, block // stride) * stride    # sample points
    # Dual-diagonal sampling: anti-diagonal pairs (t, B-1-t) cover ODD
    # within-block relative offsets (2t-B+1); diagonal pairs (t, t) cover
    # offset 0 and stand in for the even band. Anti-diagonal alone — the
    # XAttention estimator — is provably blind to attention concentrated
    # at even offsets (e.g. a copy/induction edge at an exact multiple of
    # the block size), which this model's dominant head exhibits; see
    # DESIGN.md §Hardware-Adaptation.
    qs = q_ref[0].astype(jnp.float32)[t, :]                  # [B/s, dh]
    ks = k_ref[0].astype(jnp.float32)                        # [N, dh]
    ks_anti = ks.reshape(nblk, block, -1)[:, block - 1 - t, :]
    ks_diag = ks.reshape(nblk, block, -1)[:, t, :]
    routing = (jnp.einsum("td,jtd->j", qs, ks_anti)
               + jnp.einsum("td,jtd->j", qs, ks_diag)) * scale
    mv = mv_ref[0]                                           # [nk]
    m = routing + beta_ref[0] * jnp.maximum(0.0, mv)
    j = jax.lax.iota(jnp.int32, nblk)
    o_ref[0, 0] = jnp.where(j <= i, m, NEG_INF)


@functools.partial(jax.jit, static_argnames=("block", "stride"))
def oam_block_scores(q, k, v, beta, block: int = 64, stride: int = 16):
    """Output-Aware Metric M[h, i, j] (Eq. 7) via Pallas kernels.

    Args:
      q: [H, N, dh]; k, v: [Hk, N, dh]; beta: scalar (runtime).
    Returns:
      [H, nq, nk] float32 metric, causally masked to NEG_INF.
    """
    hq, n, dh = q.shape
    hk = k.shape[0]
    nblk = n // block
    rep = hq // hk
    mv = value_block_logmag(v, block)                        # [Hk, nk]
    beta_arr = jnp.asarray(beta, jnp.float32).reshape(1)
    return pl.pallas_call(
        functools.partial(_oam_kernel, block=block, stride=stride,
                          nblk=nblk, scale=1.0 / (dh ** 0.5)),
        grid=(hq, nblk),
        in_specs=[
            pl.BlockSpec((1,), lambda h, i: (0,)),
            pl.BlockSpec((1, block, dh), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, n, dh), lambda h, i: (h // rep, 0, 0)),
            pl.BlockSpec((1, nblk), lambda h, i: (h // rep, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, nblk), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((hq, nblk, nblk), jnp.float32),
        interpret=True,
    )(beta_arr, q, k, mv)
