"""Pure-jnp reference oracles for every Pallas kernel in this package.

These are the correctness ground truth: `python/tests/` asserts each Pallas
kernel `allclose` against the function of the same name here, and `aot.py`
emits golden vectors from these for the rust-side integration tests.

Shape conventions (single sequence, multi-head):
  Q        [H,  N, dh]   query, H query heads
  K, V     [Hk, N, dh]   key/value, Hk <= H kv heads (GQA: H % Hk == 0)
  indices  [H, nq, kmax] selected KV-block ids per (head, query-block)
  counts   [H, nq]       number of valid slots in `indices` (<= kmax)
"""

from __future__ import annotations

import jax.numpy as jnp

__all__ = [
    "gqa_expand",
    "pool_mean",
    "pool_antidiag_scores",
    "value_block_logmag",
    "oam_block_scores",
    "dense_attention",
    "block_sparse_attention",
    "block_causal_mask",
]

NEG_INF = -1e30


def gqa_expand(x, h_q: int):
    """Broadcast [Hk, ...] kv-head tensors to [H, ...] query heads."""
    hk = x.shape[0]
    assert h_q % hk == 0, f"GQA requires H % Hk == 0, got {h_q} % {hk}"
    rep = h_q // hk
    return jnp.repeat(x, rep, axis=0)


def pool_mean(x, block: int):
    """Mean-pool the sequence axis into blocks: [H, N, d] -> [H, N/B, d]."""
    h, n, d = x.shape
    assert n % block == 0, f"N={n} not divisible by block={block}"
    return x.reshape(h, n // block, block, d).mean(axis=2)


def pool_antidiag_scores(q, k, block: int, stride: int = 16):
    """Dual-diagonal block routing scores (XAttention-style estimator,
    extended).

    For query block i and key block j the estimator samples the block
    pair's anti-diagonal AND diagonal at `stride`:
        score(i, j) = sum_t q[iB + ts] . (k[jB + (B-1-ts)] + k[jB + ts])
                      / sqrt(d).
    Anti-diagonal pairs cover odd within-block relative offsets (2t-B+1),
    diagonal pairs cover offset 0 / the even band — anti-diagonal alone is
    blind to attention concentrated at even offsets (e.g. induction/copy
    edges at exact block multiples). Cost is still O(B/s) rows per block
    pair, 2x the pure anti-diagonal sample count.

    Returns [H, nq, nk] (kv heads broadcast to query heads).
    """
    hq, n, d = q.shape
    assert n % block == 0 and block % stride == 0
    nblk = n // block
    t = jnp.arange(0, block, stride)
    k = gqa_expand(k, hq)
    qs = q.reshape(hq, nblk, block, d)[:, :, t, :]          # [H, nb, B/s, d]
    kb = k.reshape(hq, nblk, block, d)
    ks = kb[:, :, block - 1 - t, :] + kb[:, :, t, :]
    scores = jnp.einsum("hitd,hjtd->hij", qs.astype(jnp.float32),
                        ks.astype(jnp.float32)) / jnp.sqrt(float(d))
    return scores


def value_block_logmag(v, block: int, h_q: int):
    """Block max-pooled value log-magnitude M_V (Algorithm 1, line 6).

    [Hk, N, d] -> [H, N/B] where entry (h, j) = max over tokens in block j
    of log ||V_t||_2, broadcast to query heads.
    """
    hk, n, d = v.shape
    nblk = n // block
    mag = jnp.log(jnp.linalg.norm(v.astype(jnp.float32), axis=-1) + 1e-12)
    pooled = mag.reshape(hk, nblk, block).max(axis=2)
    return gqa_expand(pooled, h_q)


def block_causal_mask(nblk: int):
    """[nq, nk] bool, True where key block j is visible to query block i."""
    i = jnp.arange(nblk)[:, None]
    j = jnp.arange(nblk)[None, :]
    return j <= i


def oam_block_scores(q, k, v, block: int, beta, stride: int = 16):
    """Output-Aware Metric at block granularity, Eq. (7).

    M[h, i, j] = routing(i, j) + beta * max(0, pooled log||V_j||),
    with causally masked (j > i) entries at -inf. `beta == 0` degrades to
    the Score-Aware Metric (SAM) used by prior work.
    """
    hq = q.shape[0]
    routing = pool_antidiag_scores(q, k, block, stride)
    mv = value_block_logmag(v, block, hq)                    # [H, nk]
    m = routing + beta * jnp.maximum(0.0, mv)[:, None, :]
    nblk = q.shape[1] // block
    return jnp.where(block_causal_mask(nblk)[None], m, NEG_INF)


def dense_attention(q, k, v):
    """Exact causal softmax attention with GQA broadcast. [H, N, dh] out."""
    hq, n, d = q.shape
    k = gqa_expand(k, hq)
    v = gqa_expand(v, hq)
    s = jnp.einsum("hid,hjd->hij", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(float(d))
    mask = jnp.tril(jnp.ones((n, n), bool))
    s = jnp.where(mask[None], s, NEG_INF)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("hij,hjd->hid", p, v.astype(p.dtype)).astype(q.dtype)


def block_sparse_attention(q, k, v, indices, counts, block: int):
    """Oracle for the block-sparse kernel: renormalized softmax over the
    union of selected KV blocks, with the within-block causal mask applied
    to the diagonal block (Algorithm 1 steps c-d).

    Selection semantics: for query block i, the visible key set is
    {tokens of block indices[h, i, t] : t < counts[h, i]}; duplicate block
    ids contribute once (a keep-mask is built, not a gather).
    """
    hq, n, d = q.shape
    nblk = n // block
    kmax = indices.shape[-1]
    k = gqa_expand(k, hq)
    v = gqa_expand(v, hq)

    slot = jnp.arange(kmax)[None, None, :]
    valid = slot < counts[:, :, None]                        # [H, nq, kmax]
    # keep[h, i, b] = True iff block b selected for query block i.
    onehot = jnp.zeros((hq, nblk, nblk), bool).at[
        jnp.arange(hq)[:, None, None],
        jnp.arange(nblk)[None, :, None],
        indices,
    ].max(valid)
    keep = onehot & block_causal_mask(nblk)[None]

    s = jnp.einsum("hid,hjd->hij", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / jnp.sqrt(float(d))
    tok_keep = jnp.repeat(jnp.repeat(keep, block, axis=1), block, axis=2)
    causal = jnp.tril(jnp.ones((n, n), bool))[None]
    s = jnp.where(tok_keep & causal, s, NEG_INF)
    m = s.max(axis=-1, keepdims=True)
    p = jnp.exp(s - m)
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("hij,hjd->hid", p, v.astype(p.dtype)).astype(q.dtype)
