"""Pallas dense causal flash-attention kernel (the latency baseline).

Identical online-softmax structure to `block_sparse.py` but iterates all
`qb + 1` consecutive KV blocks — i.e. the FlashAttention-2 schedule the
paper benchmarks against. Keeping both kernels structurally parallel makes
the measured dense-vs-sparse latency gap attributable to the budget alone.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, *, block: int, dh: int,
            scale: float):
    qb = pl.program_id(1)
    q = q_ref[0].astype(jnp.float32) * scale                 # [B, dh]

    rows = jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)

    def body(t, carry):
        m, l, acc = carry
        kblk = pl.load(
            k_ref, (0, pl.dslice(t * block, block), slice(None))
        ).astype(jnp.float32)
        vblk = pl.load(
            v_ref, (0, pl.dslice(t * block, block), slice(None))
        ).astype(jnp.float32)
        s = jax.lax.dot_general(
            q, kblk, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)
        s = jnp.where((t != qb) | (cols <= rows), s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, vblk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    init = (
        jnp.full((block,), NEG_INF, jnp.float32),
        jnp.zeros((block,), jnp.float32),
        jnp.zeros((block, dh), jnp.float32),
    )
    m, l, acc = jax.lax.fori_loop(0, qb + 1, body, init)
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block",))
def dense_attention(q, k, v, block: int = 64):
    """Exact causal attention, flash schedule. q:[H,N,dh], k/v:[Hk,N,dh]."""
    hq, n, dh = q.shape
    hk = k.shape[0]
    assert n % block == 0
    nblk = n // block
    rep = hq // hk
    return pl.pallas_call(
        functools.partial(_kernel, block=block, dh=dh,
                          scale=1.0 / (dh ** 0.5)),
        grid=(hq, nblk),
        in_specs=[
            pl.BlockSpec((1, block, dh), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, n, dh), lambda h, i: (h // rep, 0, 0)),
            pl.BlockSpec((1, n, dh), lambda h, i: (h // rep, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block, dh), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((hq, n, dh), q.dtype),
        interpret=True,
    )(q, k, v)
