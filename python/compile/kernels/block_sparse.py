"""Pallas block-sparse causal attention kernel (the paper's hot path).

This is the Stem analogue of the Triton Block-Sparse-Attention kernel the
paper builds on (Guo et al., 2024), rethought for the TPU execution model
(see DESIGN.md §Hardware-Adaptation):

  * one grid cell per (query head, query block)  — the paper's threadblock
  * the per-row selected KV-block id list arrives as an `indices` operand
    plus a `counts` operand (the Triton kernel reads block metadata from
    CSR-style arrays)
  * the inner loop is an *online-softmax* (flash-style) accumulation over
    the `counts[h, i]` selected blocks only — a `fori_loop` with a dynamic
    trip count, so the compiled module's work genuinely scales with the
    Token Position-Decay budget k(i), not with kmax
  * K/V blocks are pulled with dynamic slices (`pl.load` + `pl.dslice`) —
    the HBM→VMEM gather the paper does with tl.load on block pointers

The kernel is numerically the renormalized sparse softmax of Algorithm 1
(steps c-d) and is asserted against `ref.block_sparse_attention`.

Must run with `interpret=True`: the CPU PJRT client cannot execute Mosaic
custom-calls; interpret-mode lowers to plain HLO, which XLA-CPU compiles to
native code on the rust side.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _kernel(idx_ref, cnt_ref, q_ref, k_ref, v_ref, o_ref, *, block: int,
            dh: int, scale: float):
    qb = pl.program_id(1)
    count = cnt_ref[0, 0]
    q = q_ref[0].astype(jnp.float32) * scale                 # [B, dh]

    rows = jax.lax.broadcasted_iota(jnp.int32, (block, block), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (block, block), 1)

    def body(t, carry):
        m, l, acc = carry
        bidx = idx_ref[0, 0, t]
        kblk = pl.load(
            k_ref, (0, pl.dslice(bidx * block, block), slice(None))
        ).astype(jnp.float32)                                # [B, dh]
        vblk = pl.load(
            v_ref, (0, pl.dslice(bidx * block, block), slice(None))
        ).astype(jnp.float32)
        s = jax.lax.dot_general(
            q, kblk, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32)              # [B, B]
        # Within-block causal mask applies only on the diagonal block;
        # selection guarantees bidx <= qb, so off-diagonal blocks are
        # fully visible.
        s = jnp.where((bidx != qb) | (cols <= rows), s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, vblk, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    init = (
        jnp.full((block,), NEG_INF, jnp.float32),
        jnp.zeros((block,), jnp.float32),
        jnp.zeros((block, dh), jnp.float32),
    )
    m, l, acc = jax.lax.fori_loop(0, count, body, init)
    # counts >= 1 always (the diagonal block is forced), so l > 0.
    o_ref[0] = (acc / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block",))
def block_sparse_attention(q, k, v, indices, counts, block: int = 64):
    """Sparse causal attention over selected KV blocks.

    Args:
      q: [H, N, dh] queries.
      k, v: [Hk, N, dh] keys/values (GQA: H % Hk == 0).
      indices: [H, nq, kmax] int32 selected block ids; valid slots must be
        unique and satisfy indices <= query block id (causal).
      counts: [H, nq] int32 number of valid slots, >= 1.
      block: block size B (sequence length must be divisible by B).

    Returns:
      [H, N, dh] attention output, dtype of q.
    """
    hq, n, dh = q.shape
    hk = k.shape[0]
    assert n % block == 0, f"N={n} % block={block} != 0"
    nblk = n // block
    kmax = indices.shape[-1]
    rep = hq // hk

    grid = (hq, nblk)
    return pl.pallas_call(
        functools.partial(_kernel, block=block, dh=dh,
                          scale=1.0 / (dh ** 0.5)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, kmax), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, 1), lambda h, i: (h, i)),
            pl.BlockSpec((1, block, dh), lambda h, i: (h, i, 0)),
            pl.BlockSpec((1, n, dh), lambda h, i: (h // rep, 0, 0)),
            pl.BlockSpec((1, n, dh), lambda h, i: (h // rep, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block, dh), lambda h, i: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((hq, n, dh), q.dtype),
        interpret=True,
    )(indices, counts, q, k, v)
