"""Block-selection strategies: Stem and every baseline the paper compares.

Every method maps (Q, K, V) to the uniform kernel interface consumed by
`kernels.block_sparse.block_sparse_attention`:

    indices [H, nq, nblk] int32   selected block ids, best-first
    counts  [H, nq]       int32   number of valid slots (>= 1)

plus a scalar *budget fraction* = selected causal block pairs / all causal
block pairs (the BUD column of Tables 2 and 4).

Methods (paper §3.1 baselines):
  dense            — all causal blocks (FlashAttention-2 reference)
  stem             — TPD schedule (Eq. 3) + OAM metric (Eq. 7); with
                     runtime scalars (k_start, mu, beta) this single graph
                     also serves `uniform SAM` (mu=1, beta=0), `+TPD`
                     (beta=0) and the Figure-5 sweeps
  streaming        — StreamingLLM: sink blocks + local window, static
  xattn_like       — XAttention: anti-diagonal scores, per-row cumulative
                     softmax-mass threshold tau
  minference_like  — MInference: vertical (global top columns estimated
                     from the last query window) + slash (diagonal bands)
  flexprefill_like — FlexPrefill: per-head choice between the streaming
                     pattern and adaptive cumulative-mass selection, driven
                     by the estimated score entropy of the last query block
  segment          — diagnostic for Figure 3: uniform top-k (or ratio)
                     restricted to query blocks in [seg_lo, seg_hi), dense
                     elsewhere

All selection math is static-shape (top-k width = nblk); *cost* dynamics
come from `counts`, which bounds the kernel's online-softmax loop.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import metric as metric_k
from .kernels import ref
from . import schedule as sched

FORCE_BIAS = 1e9
NEG_INF = -1e30


def _topk_order(scores, force):
    """Order blocks best-first with forced blocks in front.

    scores: [H, nq, nk] (causally masked to NEG_INF); force: bool same
    shape. Returns indices [H, nq, nk] — a permutation of 0..nk-1 per row.
    """
    biased = jnp.where(force, scores + FORCE_BIAS, scores)
    # full-width descending argsort instead of lax.top_k: jax lowers top_k
    # to the TopK HLO whose `largest=` attribute the xla_extension 0.5.1
    # text parser rejects; sort round-trips. k == width so they're
    # equivalent.
    idx = jnp.argsort(-biased, axis=-1)
    return idx.astype(jnp.int32)


def _forced_mask(nblk: int, init_keep, local_keep):
    """[nq, nk] bool: sink blocks + local window (diag included)."""
    i = jnp.arange(nblk)[:, None]
    j = jnp.arange(nblk)[None, :]
    sink = j < init_keep
    local = (j <= i) & (j > i - local_keep)
    return (sink & (j <= i)) | local


def _budget_fraction(counts, nblk: int):
    total = counts.shape[0] * nblk * (nblk + 1) / 2.0
    return counts.sum().astype(jnp.float32) / total


def select_dense(q, block: int):
    hq, n, _ = q.shape
    nblk = n // block
    idx = jnp.broadcast_to(jnp.arange(nblk, dtype=jnp.int32),
                           (hq, nblk, nblk))
    cnt = jnp.broadcast_to(jnp.arange(1, nblk + 1, dtype=jnp.int32),
                           (hq, nblk))
    return idx, cnt, jnp.float32(1.0)


def select_stem(q, k, v, block: int, k_start, mu, beta,
                init_keep: int = 1, local_keep: int = 2, min_total: int = 3,
                stride: int = 16):
    """Stem = Output-Aware Metric ranking + Token Position-Decay budget.

    `k_start`, `mu`, `beta` may be runtime scalars (traced), enabling one
    AOT module to serve stem / uniform / +TPD / hyperparameter sweeps.
    """
    hq, n, _ = q.shape
    nblk = n // block
    scores = metric_k.oam_block_scores(q, k, v, beta, block, stride)
    force = _forced_mask(nblk, init_keep, local_keep)[None]
    order = _topk_order(scores, force)
    kvec = sched.block_budget_schedule_jnp(
        nblk, k_start, mu, init_keep, local_keep, min_total)
    cnt = jnp.broadcast_to(kvec.astype(jnp.int32), (hq, nblk))
    return order, cnt, _budget_fraction(cnt, nblk)


def select_streaming(q, block: int, sink_blocks, local_blocks):
    """StreamingLLM pattern: first `sink_blocks` + last `local_blocks`."""
    hq, n, _ = q.shape
    nblk = n // block
    keep = _forced_mask(nblk, sink_blocks, local_blocks)     # [nq, nk]
    i = jnp.arange(nblk)[:, None]
    j = jnp.arange(nblk)[None, :]
    # Rank: kept blocks first (locals before sinks is irrelevant), then a
    # deterministic causal fill for the unused slots.
    scores = jnp.where(keep & (j <= i), 1.0, NEG_INF)
    scores = jnp.broadcast_to(scores, (hq, nblk, nblk))
    order = _topk_order(scores, jnp.zeros_like(scores, bool))
    cnt = jnp.broadcast_to(keep.sum(-1).astype(jnp.int32), (hq, nblk))
    cnt = jnp.maximum(cnt, 1)
    return order, cnt, _budget_fraction(cnt, nblk)


def _row_probs(scores):
    """Softmax over the causally valid blocks of each row (f32)."""
    m = scores.max(axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    return e / e.sum(axis=-1, keepdims=True)


def select_xattn(q, k, v, block: int, tau, init_keep: int = 1,
                 local_keep: int = 1, stride: int = 16):
    """XAttention-like: keep the smallest prefix of anti-diagonal-scored
    blocks whose softmax mass reaches `tau` (runtime scalar), plus forced
    sink/diagonal blocks."""
    hq, n, _ = q.shape
    nblk = n // block
    scores = metric_k.oam_block_scores(q, k, v, 0.0, block, stride)
    force = _forced_mask(nblk, init_keep, local_keep)[None]
    order = _topk_order(scores, force)
    probs = _row_probs(scores)                               # [H, nq, nk]
    sorted_p = jnp.take_along_axis(probs, order, axis=-1)
    cum = jnp.cumsum(sorted_p, axis=-1)
    # count = 1 + #{prefix cumsum < tau}, clamped to the causal width.
    cnt = 1 + (cum < tau).sum(axis=-1).astype(jnp.int32)
    forced_n = force.sum(-1).astype(jnp.int32)
    width = jnp.arange(1, nblk + 1, dtype=jnp.int32)[None]
    cnt = jnp.minimum(jnp.maximum(cnt, forced_n), width)
    return order, cnt, _budget_fraction(cnt, nblk)


def select_minference(q, k, v, block: int, n_vertical, n_slash,
                      last_q_blocks: int = 1, stride: int = 16):
    """MInference-like vertical-slash at block granularity.

    Vertical columns are estimated from the mean routing score of the last
    `last_q_blocks` query blocks (MInference's last-q estimation); slash
    keeps `n_slash` diagonal bands. Both widths are runtime scalars.
    """
    hq, n, _ = q.shape
    nblk = n // block
    scores = metric_k.oam_block_scores(q, k, v, 0.0, block, stride)
    col = scores[:, nblk - last_q_blocks:, :].mean(axis=1)   # [H, nk]
    col_order = jnp.argsort(-col, axis=-1)                   # see _topk_order
    rank = jnp.zeros((hq, nblk), jnp.int32).at[
        jnp.arange(hq)[:, None], col_order].set(
        jnp.broadcast_to(jnp.arange(nblk, dtype=jnp.int32), (hq, nblk)))
    vertical = (rank < n_vertical)[:, None, :]               # [H, 1, nk]
    i = jnp.arange(nblk)[:, None]
    j = jnp.arange(nblk)[None, :]
    slash = (j <= i) & (j > i - n_slash)
    keep = (vertical | slash[None]) & (j <= i)[None]
    keep = keep | _forced_mask(nblk, 1, 1)[None]
    sel_scores = jnp.where(keep, scores, NEG_INF)
    order = _topk_order(sel_scores, jnp.zeros_like(keep))
    cnt = jnp.maximum(keep.sum(-1).astype(jnp.int32), 1)
    return order, cnt, _budget_fraction(cnt, nblk)


def select_flexprefill(q, k, v, block: int, gamma, entropy_thresh,
                       sink_blocks: int = 1, local_blocks: int = 2,
                       stride: int = 16):
    """FlexPrefill-like: per-head pattern choice + adaptive budget.

    A head whose last-query-block score distribution has low entropy is
    judged "structured" and gets the cheap streaming pattern; otherwise it
    gets query-aware cumulative-mass selection with coverage `gamma`.
    """
    hq, n, _ = q.shape
    nblk = n // block
    scores = metric_k.oam_block_scores(q, k, v, 0.0, block, stride)
    probs = _row_probs(scores)
    last = probs[:, -1, :]                                   # [H, nk]
    ent = -(last * jnp.log(last + 1e-12)).sum(-1)            # [H]
    norm_ent = ent / jnp.log(float(nblk))
    use_stream = norm_ent < entropy_thresh                   # [H]

    force = _forced_mask(nblk, sink_blocks, local_blocks)[None]
    order = _topk_order(scores, force)
    sorted_p = jnp.take_along_axis(probs, order, axis=-1)
    cum = jnp.cumsum(sorted_p, axis=-1)
    cnt_adapt = 1 + (cum < gamma).sum(axis=-1).astype(jnp.int32)
    forced_n = force.sum(-1).astype(jnp.int32)
    width = jnp.arange(1, nblk + 1, dtype=jnp.int32)[None]
    cnt_adapt = jnp.minimum(jnp.maximum(cnt_adapt, forced_n), width)

    keep_stream = _forced_mask(nblk, sink_blocks, local_blocks)
    cnt_stream = jnp.broadcast_to(
        jnp.maximum(keep_stream.sum(-1).astype(jnp.int32), 1), (hq, nblk))
    # Streaming heads order by the forced mask, adaptive heads by score.
    stream_scores = jnp.where(keep_stream[None], FORCE_BIAS / 2, scores)
    order_stream = _topk_order(stream_scores, jnp.zeros_like(force))
    order = jnp.where(use_stream[:, None, None], order_stream, order)
    cnt = jnp.where(use_stream[:, None], cnt_stream, cnt_adapt)
    return order, cnt, _budget_fraction(cnt, nblk)


def select_segment(q, k, v, block: int, seg_lo, seg_hi, k_seg, ratio,
                   stride: int = 16):
    """Figure-3 diagnostic: sparsify only query blocks in [seg_lo, seg_hi).

    Inside the segment rows use SAM top-k with either a fixed budget
    `k_seg` (if ratio <= 0) or a dynamic budget ceil(ratio * (i+1));
    outside the segment rows are dense. All four knobs are runtime scalars.
    """
    hq, n, _ = q.shape
    nblk = n // block
    scores = metric_k.oam_block_scores(q, k, v, 0.0, block, stride)
    force = _forced_mask(nblk, 1, 1)[None]
    order = _topk_order(scores, force)
    i = jnp.arange(nblk, dtype=jnp.int32)
    width = i + 1
    in_seg = (i >= seg_lo) & (i < seg_hi)
    k_fixed = jnp.broadcast_to(jnp.asarray(k_seg, jnp.int32), (nblk,))
    k_ratio = jnp.ceil(ratio * width.astype(jnp.float32)).astype(jnp.int32)
    k_sparse = jnp.where(ratio > 0, k_ratio, k_fixed)
    cnt_row = jnp.where(in_seg, jnp.clip(k_sparse, 1, width), width)
    cnt = jnp.broadcast_to(cnt_row, (hq, nblk))
    return order, cnt, _budget_fraction(cnt, nblk)


# --- pure-jnp reference selection (oracle for pytest) ----------------------


def select_stem_ref(q, k, v, block: int, k_start, mu, beta,
                    init_keep: int = 1, local_keep: int = 2,
                    min_total: int = 3, stride: int = 16):
    """Same as `select_stem` but on the jnp metric oracle (ref.py).

    Ranks with `lax.top_k` instead of argsort: this path runs under
    vmap+grad during native-sparse TRAINING, where argsort's batched
    gather is unsupported by this jax/xla combo — while the AOT parser
    constraint that forced argsort (DESIGN.md §2) only applies to lowered
    prefill graphs, which use `select_stem`.
    """
    hq, n, _ = q.shape
    nblk = n // block
    scores = ref.oam_block_scores(q, k, v, block, beta, stride)
    force = _forced_mask(nblk, init_keep, local_keep)[None]
    biased = jnp.where(force, scores + FORCE_BIAS, scores)
    _, order = jax.lax.top_k(biased, nblk)
    order = order.astype(jnp.int32)
    kvec = sched.block_budget_schedule_jnp(
        nblk, k_start, mu, init_keep, local_keep, min_total)
    cnt = jnp.broadcast_to(kvec.astype(jnp.int32), (hq, nblk))
    return order, cnt, _budget_fraction(cnt, nblk)
