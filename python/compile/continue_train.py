"""Continuation training: extend the base checkpoint's long-context
competence (copy rungs at 1024/2048 need ~60-80 steps to crack; the main
schedule under-allocated them — see EXPERIMENTS.md §Training).

Usage:  cd python && PYTHONPATH=. python -m compile.continue_train \
            [--phases "copy:1024:4:80,copy:2048:2:40,tasks:1024:4:40"]

Loads artifacts/ckpt_base.npz, trains the extra phases, overwrites the
checkpoint and train log ("_cont" suffixed). `make artifacts` then reuses
the improved checkpoint and re-exports weights.
"""

from __future__ import annotations

import argparse
import os

import jax.numpy as jnp
import numpy as np

from . import model as M
from . import train

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def parse_phases(spec: str):
    out = []
    for part in spec.split(","):
        kind, n, b, s = part.strip().split(":")
        out.append((kind, int(n), int(b), int(s)))
    return tuple(out)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt", default="base")
    ap.add_argument(
        "--phases",
        default="copy:1024:4:90,copy:2048:2:50,tasks:1024:4:50,tasks:2048:2:24",
    )
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--native-k", type=float, default=0.0,
                    help="train with uniform block-top-k (native ckpt)")
    args = ap.parse_args()

    cfg = M.ModelConfig()
    npz = os.path.join(ART, f"ckpt_{args.ckpt}.npz")
    data = np.load(npz)
    flat = [jnp.asarray(data[n]) for n, _ in M.param_spec(cfg)]
    init = M.unflatten_params(cfg, flat)

    params, log = train.train(
        cfg,
        name=f"{args.ckpt}_cont",
        phases=parse_phases(args.phases),
        lr=args.lr,
        native_k=args.native_k,
        init=init,
    )
    flat = M.flatten_params(cfg, params)
    np.savez(npz, **{n: np.asarray(a) for (n, _), a in
                     zip(M.param_spec(cfg), flat)})
    train.save_log(log, os.path.join(ART, f"train_log_{args.ckpt}_cont.json"))
    print(f"[continue_train] {npz} updated", flush=True)


if __name__ == "__main__":
    main()
