"""L2: GPT-style causal transformer with pluggable sparse attention.

Architecture (a scaled-down Llama/Qwen shape — see DESIGN.md §4 for the
substitution argument): RMSNorm, rotary position embeddings, grouped-query
attention, SwiGLU MLP, tied embeddings.

Two execution paths share the same parameters and math:

  * `forward(..., attn="jnp")` — pure-jnp dense attention; fast under XLA
    fusion; used for *training* and as the logits oracle in tests.
  * `forward(..., attn=<method>)` — the AOT path: per-layer Q/K/V run the
    selection method from `methods.py` and the Pallas block-sparse kernel
    (`kernels/block_sparse.py`). This is what gets lowered to HLO text and
    served by the rust coordinator.

Prefill graphs operate on a single sequence (batch is the coordinator's
job); training uses `vmap` over the batch axis.
"""

from __future__ import annotations

from dataclasses import dataclass, asdict

import jax
import jax.numpy as jnp
import numpy as np

from . import methods
from .kernels import block_sparse, dense as dense_k, ref
from .tasks import VOCAB_SIZE


@dataclass(frozen=True)
class ModelConfig:
    """Default geometry sized for the single-core CPU testbed (DESIGN.md
    §4): deep enough for induction circuits + the Table-1 depth story,
    small enough that training reaches task competence within the build
    budget and a 2048-token prefill stays sub-second."""

    vocab_size: int = VOCAB_SIZE
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    n_kv_heads: int = 2
    d_ff: int = 344
    rope_base: float = 10000.0
    block: int = 64              # sparse attention block size B
    init_keep: int = 1           # forced sink blocks
    local_keep: int = 2          # forced local-window blocks
    min_total: int = 4           # per-row budget floor: forced sink+local (3) + >=1 metric-chosen slot
    metric_stride: int = 16      # anti-diagonal sampling stride

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    def to_dict(self) -> dict:
        return asdict(self)


def init_params(cfg: ModelConfig, seed: int = 0) -> dict:
    """Scaled-normal init; returns a flat-ish pytree (dict of dicts)."""
    rng = np.random.default_rng(seed)
    d = cfg.d_model
    hk = cfg.n_kv_heads * cfg.d_head

    def mat(shape, scale):
        return jnp.asarray(rng.normal(0, scale, shape), jnp.float32)

    params = {
        "embed": mat((cfg.vocab_size, d), 0.02),
        "ln_f": jnp.ones((d,), jnp.float32),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        params["layers"].append({
            "ln1": jnp.ones((d,), jnp.float32),
            "wq": mat((d, d), d ** -0.5),
            "wk": mat((d, hk), d ** -0.5),
            "wv": mat((d, hk), d ** -0.5),
            "wo": mat((d, d), (d * 2 * cfg.n_layers) ** -0.5),
            "ln2": jnp.ones((d,), jnp.float32),
            "w_gate": mat((d, cfg.d_ff), d ** -0.5),
            "w_up": mat((d, cfg.d_ff), d ** -0.5),
            "w_down": mat((cfg.d_ff, d), (cfg.d_ff * 2 * cfg.n_layers) ** -0.5),
        })
    return params


# --- parameter flattening (stable order shared with aot.py / rust) ---------


def param_spec(cfg: ModelConfig) -> list[tuple[str, tuple[int, ...]]]:
    """Canonical (name, shape) list — the AOT input order and the layout
    of the weights file consumed by the rust runtime."""
    d = cfg.d_model
    hk = cfg.n_kv_heads * cfg.d_head
    spec = [("embed", (cfg.vocab_size, d))]
    for i in range(cfg.n_layers):
        spec += [
            (f"layers.{i}.ln1", (d,)),
            (f"layers.{i}.wq", (d, d)),
            (f"layers.{i}.wk", (d, hk)),
            (f"layers.{i}.wv", (d, hk)),
            (f"layers.{i}.wo", (d, d)),
            (f"layers.{i}.ln2", (d,)),
            (f"layers.{i}.w_gate", (d, cfg.d_ff)),
            (f"layers.{i}.w_up", (d, cfg.d_ff)),
            (f"layers.{i}.w_down", (cfg.d_ff, d)),
        ]
    spec.append(("ln_f", (d,)))
    return spec


def flatten_params(cfg: ModelConfig, params: dict) -> list:
    out = [params["embed"]]
    for lyr in params["layers"]:
        out += [lyr["ln1"], lyr["wq"], lyr["wk"], lyr["wv"], lyr["wo"],
                lyr["ln2"], lyr["w_gate"], lyr["w_up"], lyr["w_down"]]
    out.append(params["ln_f"])
    assert len(out) == len(param_spec(cfg))
    return out


def unflatten_params(cfg: ModelConfig, flat: list) -> dict:
    it = iter(flat)
    params = {"embed": next(it), "layers": []}
    for _ in range(cfg.n_layers):
        params["layers"].append({
            "ln1": next(it), "wq": next(it), "wk": next(it),
            "wv": next(it), "wo": next(it), "ln2": next(it),
            "w_gate": next(it), "w_up": next(it), "w_down": next(it),
        })
    params["ln_f"] = next(it)
    return params


# --- building blocks --------------------------------------------------------


def rmsnorm(x, g, eps: float = 1e-6):
    var = jnp.mean(x.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * g).astype(x.dtype)


def rope(x, base: float):
    """Rotary embeddings over [H, N, dh]."""
    h, n, dh = x.shape
    half = dh // 2
    freqs = base ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = jnp.arange(n, dtype=jnp.float32)[:, None] * freqs[None, :]
    cos, sin = jnp.cos(angles), jnp.sin(angles)            # [N, dh/2]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1).astype(x.dtype)


def _qkv(cfg: ModelConfig, lyr: dict, x):
    """x [N, d] -> q [H, N, dh], k/v [Hk, N, dh], RoPE applied to q/k."""
    n = x.shape[0]
    dh = cfg.d_head
    q = (x @ lyr["wq"]).reshape(n, cfg.n_heads, dh).transpose(1, 0, 2)
    k = (x @ lyr["wk"]).reshape(n, cfg.n_kv_heads, dh).transpose(1, 0, 2)
    v = (x @ lyr["wv"]).reshape(n, cfg.n_kv_heads, dh).transpose(1, 0, 2)
    return rope(q, cfg.rope_base), rope(k, cfg.rope_base), v


def _merge_heads(o):
    """[H, N, dh] -> [N, H*dh]."""
    h, n, dh = o.shape
    return o.transpose(1, 0, 2).reshape(n, h * dh)


def _mlp(lyr, x):
    return (jax.nn.silu(x @ lyr["w_gate"]) * (x @ lyr["w_up"])) @ lyr["w_down"]


# --- attention method dispatch ----------------------------------------------


def attention(cfg: ModelConfig, q, k, v, method: str, hparams: dict):
    """Dispatch to a selection method + the block-sparse kernel.

    Returns (output [H, N, dh], budget_fraction scalar).
    """
    b = cfg.block
    if method == "jnp":
        return ref.dense_attention(q, k, v), jnp.float32(1.0)
    if method == "jnp_topk":
        # Differentiable uniform block-top-k (SAM) attention used to TRAIN
        # the "native sparse" model of Table 3 (InfLLMv2/DSA stand-in):
        # the hard block mask is data-dependent but gradients flow through
        # the selected paths.
        idx, cnt, bud = methods.select_stem_ref(
            q, k, v, b, float(hparams["k_native"]), 1.0, 0.0,
            cfg.init_keep, cfg.local_keep, cfg.min_total, cfg.metric_stride)
        return ref.block_sparse_attention(q, k, v, idx, cnt, b), bud
    if method == "dense":
        return dense_k.dense_attention(q, k, v, block=b), jnp.float32(1.0)
    if method == "stem":
        idx, cnt, bud = methods.select_stem(
            q, k, v, b, hparams["k_start"], hparams["mu"], hparams["beta"],
            cfg.init_keep, cfg.local_keep, cfg.min_total, cfg.metric_stride)
    elif method == "streaming":
        idx, cnt, bud = methods.select_streaming(
            q, b, hparams["sink_blocks"], hparams["local_blocks"])
    elif method == "xattn":
        idx, cnt, bud = methods.select_xattn(
            q, k, v, b, hparams["tau"], cfg.init_keep, 1, cfg.metric_stride)
    elif method == "minference":
        idx, cnt, bud = methods.select_minference(
            q, k, v, b, hparams["n_vertical"], hparams["n_slash"],
            stride=cfg.metric_stride)
    elif method == "flexprefill":
        idx, cnt, bud = methods.select_flexprefill(
            q, k, v, b, hparams["gamma"], hparams["entropy_thresh"],
            cfg.init_keep, cfg.local_keep, cfg.metric_stride)
    elif method == "segment":
        idx, cnt, bud = methods.select_segment(
            q, k, v, b, hparams["seg_lo"], hparams["seg_hi"],
            hparams["k_seg"], hparams["ratio"], cfg.metric_stride)
    else:
        raise ValueError(f"unknown attention method: {method}")
    out = block_sparse.block_sparse_attention(q, k, v, idx, cnt, block=b)
    return out, bud


def forward(cfg: ModelConfig, params: dict, ids, method: str = "jnp",
            hparams: dict | None = None, collect_hidden: bool = False):
    """Single-sequence forward.

    Args:
      ids: [N] int32 token ids.
    Returns:
      (logits [N, vocab], budget_fraction scalar, hidden [L, N, d] or None)
    """
    hparams = hparams or {}
    x = params["embed"][ids]                                # [N, d]
    buds = []
    hiddens = []
    for lyr in params["layers"]:
        h = rmsnorm(x, lyr["ln1"])
        q, k, v = _qkv(cfg, lyr, h)
        o, bud = attention(cfg, q, k, v, method, hparams)
        x = x + _merge_heads(o) @ lyr["wo"]
        x = x + _mlp(lyr, rmsnorm(x, lyr["ln2"]))
        buds.append(bud)
        if collect_hidden:
            hiddens.append(x)
    x = rmsnorm(x, params["ln_f"])
    logits = x @ params["embed"].T                           # tied head
    budget = jnp.stack(buds).mean()
    hidden = jnp.stack(hiddens) if collect_hidden else None
    return logits, budget, hidden


def forward_batch_jnp(cfg: ModelConfig, params: dict, ids,
                      method: str = "jnp", hparams: dict | None = None):
    """[B, N] -> [B, N, vocab] logits, jnp paths only (training)."""
    assert method in ("jnp", "jnp_topk")
    def one(seq):
        logits, _, _ = forward(cfg, params, seq, method=method,
                               hparams=hparams)
        return logits
    return jax.vmap(one)(ids)


def lm_loss(cfg: ModelConfig, params: dict, ids, mask,
            method: str = "jnp", hparams: dict | None = None):
    """Masked next-token cross-entropy, normalized PER SAMPLE. ids/mask:
    [B, N].

    Per-sample normalization matters: a copy-replay sample supervises
    ~N/2 positions while a QA sample supervises 1-3, so token-level
    averaging lets replay drown the task gradient ~100:1 (the failure
    mode documented in EXPERIMENTS.md §Training). Each sequence
    contributes equally here.
    """
    logits = forward_batch_jnp(cfg, params, ids, method, hparams)
    logp = jax.nn.log_softmax(logits[:, :-1].astype(jnp.float32), axis=-1)
    tgt = ids[:, 1:]
    nll = -jnp.take_along_axis(logp, tgt[..., None], axis=-1)[..., 0]
    w = mask[:, 1:]
    per_sample = (nll * w).sum(-1) / jnp.maximum(w.sum(-1), 1.0)
    return per_sample.mean()
