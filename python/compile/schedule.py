"""Token Position-Decay (TPD) schedule and cost model (paper §2.1).

Implements Eq. (3) `k(i)`, the cost identities Eq. (2) `C_uni` and Eq. (4)
`C_decay`, the Stem complexity Eq. (8), and the budget-matching rule used by
the ablation (§3.3): `k_uni = k_start * (1 + mu) / 2`.

Everything here exists twice: this module (build path + oracle for pytest)
and `rust/src/sparse/schedule.rs` (request path). The two are cross-checked
through golden vectors emitted by `aot.py`.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

__all__ = [
    "TPDConfig",
    "k_at",
    "k_schedule",
    "block_budget_schedule",
    "cost_uniform",
    "cost_decay",
    "cost_stem",
    "cost_dense",
    "k_uniform_matched",
    "k_avg",
]


@dataclass(frozen=True)
class TPDConfig:
    """Hyper-parameters of the Token Position-Decay strategy.

    Attributes:
      k_start: initial per-position budget (tokens, or blocks when used at
        block granularity).
      mu: decay ratio in (0, 1]; ``k_end = mu * k_start``. ``mu == 1``
        recovers the uniform budget.
      init_keep: number of leading blocks always kept (attention-sink /
        recursive-anchor protection; paper keeps 4 blocks).
      local_keep: number of trailing (local-window) blocks always kept,
        including the diagonal block (paper keeps 4).
      min_total: floor on the per-row budget (paper enforces a minimum
        total of 54 blocks at 8B scale; scaled down here).
    """

    k_start: float
    mu: float = 0.7
    init_keep: int = 1
    local_keep: int = 2
    min_total: int = 3

    def __post_init__(self) -> None:
        if not (0.0 < self.mu <= 1.0):
            raise ValueError(f"mu must be in (0, 1], got {self.mu}")
        if self.k_start <= 0:
            raise ValueError(f"k_start must be positive, got {self.k_start}")
        if self.init_keep < 0 or self.local_keep < 1:
            raise ValueError("init_keep >= 0 and local_keep >= 1 required")


def k_at(i: int | np.ndarray, n: int, k_start: float, mu: float) -> np.ndarray:
    """Per-position budget k(i), Eq. (3).

    ``k(i) = floor(k_start - (k_start * (1 - mu) / N) * i)`` for
    ``i in {1..N}`` (paper indexing). We accept 0-based ``i`` and shift.
    """
    i1 = np.asarray(i, dtype=np.float64) + 1.0  # paper is 1-based
    k = np.floor(k_start - (k_start * (1.0 - mu) / float(n)) * i1)
    return np.maximum(k, 1.0)


def k_schedule(n: int, cfg: TPDConfig) -> np.ndarray:
    """Vector of budgets for all N positions (token granularity)."""
    return k_at(np.arange(n), n, cfg.k_start, cfg.mu)


def block_budget_schedule(n_blocks: int, cfg: TPDConfig) -> np.ndarray:
    """Effective per-query-block budget, in blocks, with causal clamping.

    Mirrors Algorithm 1 step (b): interpolate k_start -> k_end across the
    block axis, floor, then clamp to [min_total, i+1] (a row can never
    attend to more blocks than exist under the causal mask) and never below
    the forced init+local set size.
    """
    raw = k_at(np.arange(n_blocks), n_blocks, cfg.k_start, cfg.mu)
    forced = np.minimum(cfg.init_keep + cfg.local_keep, np.arange(n_blocks) + 1)
    k = np.maximum(raw, np.maximum(cfg.min_total, forced))
    return np.minimum(k, np.arange(n_blocks) + 1.0)


def cost_dense(n: int) -> float:
    """Computed token pairs under full causal attention: N(N+1)/2."""
    return n * (n + 1) / 2.0


def cost_uniform(n: int, k_uni: float) -> float:
    """Eq. (2): C_uni ~= N*k - k^2/2 (causal-triangle corrected)."""
    return n * k_uni - 0.5 * k_uni * k_uni


def cost_decay(n: int, k_start: float, mu: float) -> float:
    """Eq. (4): uniform baseline minus the decay savings term."""
    base = n * k_start - 0.5 * k_start * k_start
    savings = 0.5 * k_start * (1.0 - mu) * (n - k_start)
    return base - savings


def cost_stem(n: int, d: int, block: int, k_avg_tokens: float) -> float:
    """Eq. (8): metric calculation + sparse attention FLOP-ish count."""
    metric = 2.0 * n * n * d / (block * block) + n * d / block
    sparse = 4.0 * n * k_avg_tokens * d + 3.0 * n * k_avg_tokens
    return metric + sparse


def k_uniform_matched(k_start: float, mu: float) -> float:
    """Budget-matching rule from §3.3: k_uni = k_start * (1 + mu) / 2.

    Chosen so C_uni(k_uni) ~= C_decay(k_start, mu) for N >> k_start; the
    ablation compares Uniform vs TPD at this matched budget.
    """
    return k_start * (1.0 + mu) / 2.0


def k_avg(n: int, cfg: TPDConfig) -> float:
    """Average per-position budget, k_avg = (1/N) sum_i k(i)."""
    return float(np.mean(np.minimum(k_schedule(n, cfg), np.arange(n) + 1.0)))


# --- jnp (traceable) versions used inside the AOT'd selection graphs -------


def k_at_jnp(i, n: int, k_start, mu):
    """Traceable Eq. (3); `k_start`/`mu` may be runtime scalars."""
    i1 = i.astype(jnp.float32) + 1.0
    k = jnp.floor(k_start - (k_start * (1.0 - mu) / float(n)) * i1)
    return jnp.maximum(k, 1.0)


def block_budget_schedule_jnp(n_blocks: int, k_start, mu, init_keep: int,
                              local_keep: int, min_total):
    """Traceable `block_budget_schedule` with runtime k_start/mu/min_total."""
    idx = jnp.arange(n_blocks)
    raw = k_at_jnp(idx, n_blocks, k_start, mu)
    forced = jnp.minimum(init_keep + local_keep, idx + 1).astype(jnp.float32)
    k = jnp.maximum(raw, jnp.maximum(jnp.asarray(min_total, jnp.float32), forced))
    return jnp.minimum(k, (idx + 1).astype(jnp.float32))
