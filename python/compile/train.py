"""Build-time training of the synthetic-task backbones.

Trains two checkpoints used by the evaluation harness (DESIGN.md §4):

  * `base`   — dense-attention training on the mixed synthetic task suite;
               the stand-in for Llama-3.1-8B / Qwen3-8B dense backbones.
  * `native` — same data but trained *with* uniform block-top-k sparse
               attention in the forward pass; the stand-in for the
               training-based sparse models of Table 3 (DSA / InfLLMv2).

A curriculum over context lengths (short → long) keeps CPU cost sane while
giving RoPE exposure to every eval bucket. The loss curve is logged to
`artifacts/train_log_<name>.json` and summarized in EXPERIMENTS.md.

This module runs ONCE under `make artifacts`; nothing here is on the
serving path.
"""

from __future__ import annotations

import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from . import tasks

TRAIN_FAMILIES = list(tasks.FAMILIES) + ["multikey", "vt"]


def adam_init(params):
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree_util.tree_map(jnp.zeros_like, params),
            "t": jnp.zeros((), jnp.int32)}


def adam_step(params, grads, state, lr, b1=0.9, b2=0.95, eps=1e-8,
              clip=1.0):
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree_util.tree_leaves(grads)))
    scale = jnp.minimum(1.0, clip / (gnorm + 1e-9))
    t = state["t"] + 1
    m = jax.tree_util.tree_map(
        lambda mm, g: b1 * mm + (1 - b1) * g * scale, state["m"], grads)
    v = jax.tree_util.tree_map(
        lambda vv, g: b2 * vv + (1 - b2) * (g * scale) ** 2, state["v"], grads)
    tf = t.astype(jnp.float32)
    bc1 = 1 - b1 ** tf
    bc2 = 1 - b2 ** tf
    new = jax.tree_util.tree_map(
        lambda p, mm, vv: p - lr * (mm / bc1) / (jnp.sqrt(vv / bc2) + eps),
        params, m, v)
    return new, {"m": m, "v": v, "t": t}, gnorm


# Two-phase curriculum (EXPERIMENTS.md §Training records the calibration):
#   copy  — dense-supervision copy blocks (tasks.gen_copy) over a length
#           ladder. Builds the induction circuitry (~n/2 supervised
#           positions per sample) and gives RoPE exposure at every eval
#           offset. Sparse-supervision QA training from scratch provably
#           stalls at uniform loss on this testbed (see the calibration
#           log) — the copy phase is what makes the budget feasible.
#   tasks — the mixed QA families (answers-only loss) with a 25% copy
#           replay to prevent forgetting.
PHASES_BASE = (
    ("copy", 64, 64, 170),
    ("copy", 128, 32, 130),
    ("copy", 256, 16, 110),
    ("copy", 512, 8, 110),
)

# The native-sparse backbone (Table 3 stand-in) is FINETUNED from `base`
# with uniform block-top-k in the forward pass — the DSA/InfLLMv2 recipe
# (continued training with native sparsity), and ~6x cheaper than a
# from-scratch sparse run.
PHASES_NATIVE = (
    ("copy", 256, 16, 40),
    ("copy", 512, 8, 30),
)


def train(cfg: M.ModelConfig, name: str = "base", seed: int = 0,
          phases=PHASES_BASE, lr: float = 2e-3, native_k: float = 0.0,
          init: dict | None = None, log_every: int = 20):
    """Train a checkpoint; returns (params, log).

    phases: tuples (kind, n_ctx, batch, steps); kind ∈ {copy, tasks}.
    native_k: if > 0, train with uniform block-top-k attention of that
      budget (blocks) — the Table-3 "training-based sparse" backbone.
    init: optional starting parameters (native finetunes from base).
    """
    params = init if init is not None else M.init_params(cfg, seed=seed)
    opt = adam_init(params)
    rng = np.random.default_rng(seed + 1)
    method = "jnp_topk" if native_k > 0 else "jnp"
    hparams = {"k_native": native_k} if native_k > 0 else None

    @jax.jit
    def step_fn(params, opt, ids, mask):
        loss, grads = jax.value_and_grad(
            lambda p: M.lm_loss(cfg, p, ids, mask, method, hparams))(params)
        params, opt, gnorm = adam_step(params, grads, opt, lr)
        return params, opt, loss, gnorm

    log = {"name": name, "config": cfg.to_dict(), "native_k": native_k,
           "schedule": [list(s) for s in phases], "entries": []}
    global_step = 0
    t0 = time.time()
    for (kind, n_ctx, batch, steps) in phases:
        for s in range(steps):
            if kind == "copy":
                fams = ["copy"]
            elif kind == "qa":
                fams = ["qa_multi"]
            else:
                # replay keeps the induction circuits sharp; qa_multi
                # densifies the eval-format supervision
                fams = TRAIN_FAMILIES + ["copy", "qa_multi", "qa_multi"]
            ids, mask = tasks.gen_batch(rng, fams, n_ctx, batch)
            params, opt, loss, gnorm = step_fn(
                params, opt, jnp.asarray(ids), jnp.asarray(mask))
            global_step += 1
            if global_step % log_every == 0 or s == steps - 1:
                entry = {"step": global_step, "kind": kind, "n_ctx": n_ctx,
                         "loss": float(loss), "gnorm": float(gnorm),
                         "elapsed_s": round(time.time() - t0, 1)}
                log["entries"].append(entry)
                print(f"[train:{name}] step={global_step} {kind}@{n_ctx} "
                      f"loss={float(loss):.4f} ({entry['elapsed_s']}s)",
                      flush=True)
    return params, log


def save_log(log: dict, path: str) -> None:
    with open(path, "w") as f:
        json.dump(log, f, indent=1)
